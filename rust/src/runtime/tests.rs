//! Programming-model layer tests.
//!
//! Golden builder tests: the PR-3 API redesign moved matmul, axpy, and
//! dotp from raw `format!` strings onto the typed [`AsmBuilder`]. The
//! legacy strings are pinned *verbatim* below; each test assembles both
//! and asserts the instruction streams are identical — the property that
//! makes the redesign cycle-neutral (same instructions ⇒ same cycles on
//! a deterministic simulator).
//!
//! Registry round-trip tests: every CLI/sweep-reachable name resolves on
//! its declared targets and rejects the others with an error naming the
//! valid alternatives.

use std::collections::HashMap;

use crate::config::ClusterConfig;
use crate::isa::Program;
use crate::kernels::rt::{barrier_asm, RtLayout};
use crate::kernels::{Axpy, Dotp, Matmul};
use crate::runtime::{
    table1_workloads, workload_by_name, workload_names, AsmBuilder, Target, TargetConfig,
    Workload, WORKLOADS,
};
use crate::sim::base_symbols;

/// Assemble a workload's builder-authored program exactly as
/// `run_workload` does (builder symbols + harness defaults).
fn assemble_built(w: &dyn Workload, cfg: &ClusterConfig) -> Program {
    let tcfg = TargetConfig::Cluster(cfg.clone());
    let mut b = AsmBuilder::new();
    w.build(&tcfg, &mut b);
    let (src, mut sym) = b.finish();
    for (k, v) in base_symbols(cfg) {
        sym.entry(k).or_insert(v);
    }
    Program::assemble(&src, &sym).expect("builder program must assemble")
}

fn assemble_legacy(src: &str, mut sym: HashMap<String, u32>, cfg: &ClusterConfig) -> Program {
    for (k, v) in base_symbols(cfg) {
        sym.entry(k).or_insert(v);
    }
    Program::assemble(src, &sym).expect("legacy program must assemble")
}

fn assert_instruction_identical(kernel: &str, built: &Program, legacy: &Program) {
    assert_eq!(
        built.instrs.len(),
        legacy.instrs.len(),
        "{kernel}: instruction counts differ (builder {} vs legacy {})",
        built.instrs.len(),
        legacy.instrs.len()
    );
    for (i, (b, l)) in built.instrs.iter().zip(&legacy.instrs).enumerate() {
        assert_eq!(b, l, "{kernel}: instruction {i} differs (builder {b:?} vs legacy {l:?})");
    }
}

/// The `trace_marker` intrinsic's expected expansion, verbatim: one
/// store of the region id to `CTRL_TRACE_MARKER`.
fn legacy_trace_marker(id: u32) -> String {
    format!("la t0, TRACE_MARKER_ADDR\nli t1, {id}\nsw t1, 0(t0)\n")
}

/// The pre-redesign axpy source, verbatim.
fn legacy_axpy(k: &Axpy, cfg: &ClusterConfig) -> (String, HashMap<String, u32>) {
    let rt = RtLayout::new(cfg);
    let x = rt.data_base;
    let y = x + (k.len(cfg) * 4) as u32;
    let mut sym = HashMap::new();
    rt.add_symbols(&mut sym);
    sym.insert("vec_x".into(), x);
    sym.insert("vec_y".into(), y);
    sym.insert("ALPHA".into(), k.alpha);
    sym.insert("BLOCKS".into(), (k.per_core / 4) as u32);
    sym.insert("BLOCK_STRIDE".into(), (cfg.num_tiles() * 64) as u32);
    let src = format!(
        "\
        csrr t0, mhartid\n\
        srli t1, t0, 2\n\
        andi t2, t0, 3\n\
        # offset of this core's first island: tile*64 + lane*16\n\
        slli t3, t1, 6\n\
        slli t4, t2, 4\n\
        add t5, t3, t4\n\
        la a0, vec_x\n\
        add a0, a0, t5\n\
        la a1, vec_y\n\
        add a1, a1, t5\n\
        li a2, ALPHA\n\
        li a3, BLOCKS\n\
        li a4, BLOCK_STRIDE\n\
        {m_compute}\
        .align 8\n\
        blk:\n\
        lw t0, 0(a0)\n\
        lw t1, 4(a0)\n\
        lw t2, 8(a0)\n\
        lw t3, 12(a0)\n\
        lw t4, 0(a1)\n\
        lw t5, 4(a1)\n\
        lw t6, 8(a1)\n\
        lw a6, 12(a1)\n\
        p.mac t4, a2, t0\n\
        p.mac t5, a2, t1\n\
        p.mac t6, a2, t2\n\
        p.mac a6, a2, t3\n\
        sw t4, 0(a1)\n\
        sw t5, 4(a1)\n\
        sw t6, 8(a1)\n\
        sw a6, 12(a1)\n\
        add a0, a0, a4\n\
        add a1, a1, a4\n\
        addi a3, a3, -1\n\
        bnez a3, blk\n\
        {m_barrier}\
        {barrier}\
        halt\n",
        m_compute = legacy_trace_marker(crate::trace::REGION_COMPUTE),
        m_barrier = legacy_trace_marker(crate::trace::REGION_BARRIER),
        barrier = barrier_asm(0)
    );
    (src, sym)
}

/// The pre-redesign dotp source, verbatim.
fn legacy_dotp(k: &Dotp, cfg: &ClusterConfig) -> (String, HashMap<String, u32>) {
    let rt = RtLayout::new(cfg);
    let x = rt.data_base;
    let y = x + (k.len(cfg) * 4) as u32;
    let acc = rt.work_counter + 4;
    let mut sym = HashMap::new();
    rt.add_symbols(&mut sym);
    sym.insert("vec_x".into(), x);
    sym.insert("vec_y".into(), y);
    sym.insert("dot_acc".into(), acc);
    sym.insert("BLOCKS".into(), (k.per_core / 4) as u32);
    sym.insert("BLOCK_STRIDE".into(), (cfg.num_tiles() * 64) as u32);
    let src = format!(
        "\
        csrr t0, mhartid\n\
        srli t1, t0, 2\n\
        andi t2, t0, 3\n\
        slli t3, t1, 6\n\
        slli t4, t2, 4\n\
        add t5, t3, t4\n\
        la a0, vec_x\n\
        add a0, a0, t5\n\
        la a1, vec_y\n\
        add a1, a1, t5\n\
        li a2, 0\n\
        li a3, BLOCKS\n\
        li a4, BLOCK_STRIDE\n\
        .align 8\n\
        blk:\n\
        lw t0, 0(a0)\n\
        lw t1, 4(a0)\n\
        lw t2, 8(a0)\n\
        lw t3, 12(a0)\n\
        lw t4, 0(a1)\n\
        lw t5, 4(a1)\n\
        lw t6, 8(a1)\n\
        lw a6, 12(a1)\n\
        p.mac a2, t0, t4\n\
        p.mac a2, t1, t5\n\
        p.mac a2, t2, t6\n\
        p.mac a2, t3, a6\n\
        add a0, a0, a4\n\
        add a1, a1, a4\n\
        addi a3, a3, -1\n\
        bnez a3, blk\n\
        # reduction: one atomic add into the shared accumulator\n\
        la t0, dot_acc\n\
        amoadd.w t1, a2, (t0)\n\
        {barrier}\
        halt\n",
        barrier = barrier_asm(0)
    );
    (src, sym)
}

/// The pre-redesign matmul source, verbatim.
fn legacy_matmul(k: &Matmul, cfg: &ClusterConfig) -> (String, HashMap<String, u32>) {
    let rt = RtLayout::new(cfg);
    let a = rt.data_base;
    let b = a + (k.m * k.k * 4) as u32;
    let c = b + (k.k * k.n * 4) as u32;
    let tiles_c = k.n / 4;
    let total_tiles = (k.m / 4) * tiles_c;
    let mut sym = HashMap::new();
    rt.add_symbols(&mut sym);
    sym.insert("mat_a".into(), a);
    sym.insert("mat_b".into(), b);
    sym.insert("mat_c".into(), c);
    sym.insert("TOTAL_TILES".into(), total_tiles as u32);
    sym.insert("LOG_TILES_C".into(), tiles_c.trailing_zeros());
    sym.insert("TILES_C_MASK".into(), (tiles_c - 1) as u32);
    sym.insert("KBYTES".into(), (k.k * 4) as u32);
    sym.insert("NBYTES".into(), (k.n * 4) as u32);
    sym.insert("KDIM".into(), k.k as u32);
    sym.insert("LOG_K_B".into(), (k.k * 4).trailing_zeros());
    sym.insert("LOG_N_B".into(), (k.n * 4).trailing_zeros());

    let acc = [
        "s0", "s1", "s2", "s3", "s4", "s5", "s6", "s7", "s8", "s9", "s10", "s11", "a2", "a3",
        "a4", "a5",
    ];
    let mut src = String::new();
    src.push_str("addi sp, sp, -16\ncsrr t0, mhartid\nsw t0, 0(sp)\n");
    src.push_str(&legacy_trace_marker(crate::trace::REGION_COMPUTE));
    src.push_str(
        "\
        tile_loop:\n\
        lw t0, 0(sp)\n\
        li t1, TOTAL_TILES\n\
        bge t0, t1, tiles_done\n\
        # claim the next tile for this core\n\
        addi t1, t0, NUM_CORES\n\
        sw t1, 0(sp)\n\
        # row/col of this 4x4 tile\n\
        srli t2, t0, LOG_TILES_C\n\
        slli t2, t2, 2\n\
        andi t3, t0, TILES_C_MASK\n\
        slli t3, t3, 2\n\
        # A row pointers (a0, a1, gp, tp), stride KBYTES\n\
        slli t4, t2, LOG_K_B\n\
        la t5, mat_a\n\
        add a0, t5, t4\n\
        li t6, KBYTES\n\
        add a1, a0, t6\n\
        add gp, a1, t6\n\
        add tp, gp, t6\n\
        # B pointer: mat_b + col*4\n\
        la t5, mat_b\n\
        slli t4, t3, 2\n\
        add ra, t5, t4\n\
        # C tile pointer → 4(sp): mat_c + (row*N + col)*4\n\
        slli t4, t2, LOG_N_B\n\
        la t5, mat_c\n\
        add t5, t5, t4\n\
        slli t4, t3, 2\n\
        add t5, t5, t4\n\
        sw t5, 4(sp)\n",
    );
    for r in &acc {
        src.push_str(&format!("li {r}, 0\n"));
    }
    src.push_str(
        "\
        li a7, KDIM\n\
        .align 8\n\
        kloop:\n\
        p.lw t0, 4(a0!)\n\
        p.lw t1, 4(a1!)\n\
        p.lw t2, 4(gp!)\n\
        p.lw t3, 4(tp!)\n\
        lw t4, 0(ra)\n\
        lw t5, 4(ra)\n\
        lw t6, 8(ra)\n\
        lw a6, 12(ra)\n",
    );
    let avals = ["t0", "t1", "t2", "t3"];
    let bvals = ["t4", "t5", "t6", "a6"];
    for r in 0..4 {
        for q in 0..4 {
            src.push_str(&format!("p.mac {}, {}, {}\n", acc[4 * r + q], avals[r], bvals[q]));
        }
    }
    src.push_str(
        "\
        addi ra, ra, NBYTES\n\
        addi a7, a7, -1\n\
        bnez a7, kloop\n\
        # store the 4x4 C tile\n\
        lw t0, 4(sp)\n",
    );
    for r in 0..4 {
        for q in 0..4 {
            src.push_str(&format!("sw {}, {}(t0)\n", acc[4 * r + q], 4 * q));
        }
        if r != 3 {
            src.push_str("addi t0, t0, NBYTES\n");
        }
    }
    src.push_str("j tile_loop\ntiles_done:\n");
    src.push_str(&legacy_trace_marker(crate::trace::REGION_BARRIER));
    src.push_str(&barrier_asm(0));
    src.push_str("halt\n");
    (src, sym)
}

#[test]
fn builder_golden_axpy_matches_legacy_string() {
    let cfg = ClusterConfig::minpool();
    let k = Axpy::weak_scaled(cfg.num_cores());
    let built = assemble_built(&k, &cfg);
    let (src, sym) = legacy_axpy(&k, &cfg);
    let legacy = assemble_legacy(&src, sym, &cfg);
    assert_instruction_identical("axpy", &built, &legacy);
}

#[test]
fn builder_golden_dotp_matches_legacy_string() {
    let cfg = ClusterConfig::minpool();
    let k = Dotp::weak_scaled(cfg.num_cores());
    let built = assemble_built(&k, &cfg);
    let (src, sym) = legacy_dotp(&k, &cfg);
    let legacy = assemble_legacy(&src, sym, &cfg);
    assert_instruction_identical("dotp", &built, &legacy);
}

#[test]
fn builder_golden_matmul_matches_legacy_string() {
    let cfg = ClusterConfig::minpool();
    let k = Matmul::weak_scaled(cfg.num_cores());
    let built = assemble_built(&k, &cfg);
    let (src, sym) = legacy_matmul(&k, &cfg);
    let legacy = assemble_legacy(&src, sym, &cfg);
    assert_instruction_identical("matmul", &built, &legacy);
}

#[test]
#[should_panic(expected = "is not a register")]
fn builder_rejects_bad_registers_eagerly() {
    let mut b = AsmBuilder::new();
    b.lw("t9", 0, "a0"); // t9 does not exist
}

// ---- registry round-trip ------------------------------------------------

#[test]
fn registry_every_name_resolves_on_its_declared_targets() {
    for entry in WORKLOADS {
        for target in [Target::Cluster, Target::System] {
            let resolved = workload_by_name(entry.name, target, 16);
            if entry.supports(target) {
                let w = resolved.unwrap_or_else(|e| {
                    panic!("{} should resolve on {}: {e}", entry.name, target.name())
                });
                assert_eq!(w.name(), entry.name, "registry name and Workload::name must agree");
            } else {
                let err = resolved.err().unwrap_or_else(|| {
                    panic!("{} must be rejected on {}", entry.name, target.name())
                });
                assert!(
                    err.contains(&format!("no {}-target variant", target.name())),
                    "unsupported-target error must say so: {err}"
                );
                // The error names the valid alternatives.
                for valid in workload_names(target) {
                    assert!(err.contains(valid), "error must list `{valid}`: {err}");
                }
            }
        }
    }
}

#[test]
fn registry_rejects_unknown_names_with_alternatives() {
    let err = workload_by_name("no_such_kernel", Target::Cluster, 4).unwrap_err();
    assert!(err.contains("unknown workload"), "{err}");
    assert!(err.contains("matmul"), "error must list the known names: {err}");
}

#[test]
fn registry_target_matrix_is_stable() {
    // The CLI/sweep-reachable sets: every Table 1 kernel plus the apps
    // and double-buffered kernels on the cluster target; the sharded
    // matmul/axpy on the system target.
    assert_eq!(
        workload_names(Target::Cluster),
        vec![
            "matmul",
            "conv2d",
            "dct",
            "axpy",
            "dotp",
            "db_matmul",
            "db_axpy",
            "histeq",
            "raytrace",
            "bfs"
        ]
    );
    assert_eq!(workload_names(Target::System), vec!["matmul", "axpy", "reduce"]);
}

#[test]
fn registry_table1_suite_is_the_paper_order() {
    let cfg = ClusterConfig::minpool();
    let names: Vec<&str> = table1_workloads(&cfg).iter().map(|w| w.name()).collect();
    assert_eq!(names, vec!["matmul", "conv2d", "dct", "axpy", "dotp"]);
}

// ---- double-buffered / system golden tests ------------------------------
//
// The riskiest transcription of the redesign is the shared `DbPlumbing`
// + `emit_streamed_*` emitters, whose legacy strings (the pre-redesign
// cluster `DbPlumbing` and system `SysDbPlumbing`) were deleted. They
// are pinned verbatim below, one per target, and each variant's builder
// output must stay instruction-identical.

use crate::config::SystemConfig;
use crate::kernels::doublebuf::{DbAxpy, DbMatmul};
use crate::system::{system_symbols, SysAxpy, SysMatmul};

fn assemble_built_system(w: &dyn Workload, cfg: &SystemConfig) -> Program {
    let tcfg = TargetConfig::System(cfg.clone());
    let mut b = AsmBuilder::new();
    w.build(&tcfg, &mut b);
    let (src, mut sym) = b.finish();
    for (k, v) in system_symbols(cfg) {
        sym.entry(k).or_insert(v);
    }
    Program::assemble(&src, &sym).expect("builder program must assemble")
}

fn assemble_legacy_system(
    src: &str,
    mut sym: HashMap<String, u32>,
    cfg: &SystemConfig,
) -> Program {
    for (k, v) in system_symbols(cfg) {
        sym.entry(k).or_insert(v);
    }
    Program::assemble(src, &sym).expect("legacy program must assemble")
}

fn legacy_dma_wait(id: usize) -> String {
    format!(
        "\
        la t0, DMA_STATUS_ADDR\n\
        dma_poll_{id}: lw t1, 0(t0)\n\
        bnez t1, dma_poll_{id}\n"
    )
}

fn legacy_sdma_wait(id: usize) -> String {
    format!(
        "\
        la t0, SYSDMA_STATUS_ADDR\n\
        sdma_poll_{id}: lw t1, 0(t0)\n\
        bnez t1, sdma_poll_{id}\n"
    )
}

/// The expected `global_barrier` expansion, verbatim: local rendezvous,
/// hart 0's arrival pulse + release poll on `CTRL_GBARRIER`, and the
/// final local rendezvous.
fn legacy_global_barrier(id: usize) -> String {
    format!(
        "{b0}\
        csrr t0, mhartid\n\
        bnez t0, gbar_skip_{id}\n\
        la t1, GBARRIER_ADDR\n\
        sw zero, 0(t1)\n\
        gbar_poll_{id}:\n\
        lw t2, 0(t1)\n\
        bnez t2, gbar_poll_{id}\n\
        gbar_skip_{id}:\n\
        {b1}",
        b0 = barrier_asm(900 + 2 * id),
        b1 = barrier_asm(901 + 2 * id),
    )
}

/// The pre-redesign cluster `DbPlumbing`, verbatim.
struct LegacyDbPlumbing {
    chunk_bytes: u32,
    out_bytes: u32,
    in_bufs: [u32; 2],
    out_bufs: [u32; 2],
    l2_in: u32,
    l2_out: u32,
}

impl LegacyDbPlumbing {
    fn round_prologue(&self) -> String {
        format!(
            "\
            bnez s9, db_skip_dma\n\
            {wait}\
            # program the next round's input load (if any)\n\
            addi t0, s10, 1\n\
            bge t0, s11, db_no_next_in\n\
            li t1, {chunk}\n\
            mul t1, t0, t1\n\
            li a0, {l2_in}\n\
            add a0, a0, t1\n\
            la t0, DMA_L2_ADDR\n\
            sw a0, 0(t0)\n\
            andi t1, s10, 1\n\
            bnez t1, db_next_in_even\n\
            li a1, {in1}\n\
            j db_next_in_set\n\
            db_next_in_even:\n\
            li a1, {in0}\n\
            db_next_in_set:\n\
            la t0, DMA_SPM_ADDR\n\
            sw a1, 0(t0)\n\
            la t0, DMA_BYTES_ADDR\n\
            li t1, {chunk}\n\
            sw t1, 0(t0)\n\
            la t0, DMA_TRIGGER_ADDR\n\
            li t1, 1\n\
            sw t1, 0(t0)\n\
            db_no_next_in:\n\
            # write back the previous round's output (if any)\n\
            beqz s10, db_no_writeback\n\
            addi t0, s10, -1\n\
            li t1, {out_bytes}\n\
            mul t1, t0, t1\n\
            li a0, {l2_out}\n\
            add a0, a0, t1\n\
            la t0, DMA_L2_ADDR\n\
            sw a0, 0(t0)\n\
            andi t1, s10, 1\n\
            bnez t1, db_wb_odd\n\
            li a1, {out1}\n\
            j db_wb_set\n\
            db_wb_odd:\n\
            li a1, {out0}\n\
            db_wb_set:\n\
            la t0, DMA_SPM_ADDR\n\
            sw a1, 0(t0)\n\
            la t0, DMA_BYTES_ADDR\n\
            li t1, {out_bytes}\n\
            sw t1, 0(t0)\n\
            la t0, DMA_TRIGGER_ADDR\n\
            sw zero, 0(t0)\n\
            db_no_writeback:\n\
            db_skip_dma:\n",
            wait = legacy_dma_wait(90),
            chunk = self.chunk_bytes,
            l2_in = self.l2_in,
            in0 = self.in_bufs[0],
            in1 = self.in_bufs[1],
            out_bytes = self.out_bytes,
            l2_out = self.l2_out,
            out0 = self.out_bufs[0],
            out1 = self.out_bufs[1],
        )
    }

    fn epilogue(&self, rounds: u32) -> String {
        let last = rounds - 1;
        format!(
            "\
            bnez s9, db_skip_final\n\
            {wait}\
            li a0, {l2}\n\
            la t0, DMA_L2_ADDR\n\
            sw a0, 0(t0)\n\
            li a1, {spm}\n\
            la t0, DMA_SPM_ADDR\n\
            sw a1, 0(t0)\n\
            la t0, DMA_BYTES_ADDR\n\
            li t1, {chunk}\n\
            sw t1, 0(t0)\n\
            la t0, DMA_TRIGGER_ADDR\n\
            sw zero, 0(t0)\n\
            {wait2}\
            db_skip_final:\n",
            wait = legacy_dma_wait(91),
            wait2 = legacy_dma_wait(92),
            l2 = self.l2_out + (last * self.out_bytes),
            spm = self.out_bufs[(last & 1) as usize],
            chunk = self.out_bytes,
        )
    }
}

/// The pre-redesign system `SysDbPlumbing`, verbatim.
struct LegacySysDbPlumbing {
    chunk_bytes: u32,
    out_bytes: u32,
    in_bufs: [u32; 2],
    out_bufs: [u32; 2],
    l2_in: u32,
    l2_out: u32,
    in_shard_stride: u32,
    out_shard_stride: u32,
}

impl LegacySysDbPlumbing {
    fn program_prologue(&self, rounds: u32) -> String {
        format!(
            "\
            addi sp, sp, -32\n\
            csrr s9, mhartid\n\
            li s10, 0\n\
            li s11, {rounds}\n\
            # this cluster's shared-L2 shard bases, kept on the stack\n\
            la t0, CLUSTER_ID_ADDR\n\
            lw t1, 0(t0)\n\
            li t0, {in_stride}\n\
            mul t0, t1, t0\n\
            li a0, {l2_in}\n\
            add a0, a0, t0\n\
            sw a0, 16(sp)\n\
            li t0, {out_stride}\n\
            mul t0, t1, t0\n\
            li a0, {l2_out}\n\
            add a0, a0, t0\n\
            sw a0, 20(sp)\n",
            in_stride = self.in_shard_stride,
            out_stride = self.out_shard_stride,
            l2_in = self.l2_in,
            l2_out = self.l2_out,
        )
    }

    fn round_prologue(&self) -> String {
        format!(
            "\
            bnez s9, sdb_skip_dma\n\
            {wait}\
            # program the next round's input load (if any)\n\
            addi t0, s10, 1\n\
            bge t0, s11, sdb_no_next_in\n\
            li t1, {chunk}\n\
            mul t1, t0, t1\n\
            lw a0, 16(sp)\n\
            add a0, a0, t1\n\
            la t0, SYSDMA_L2_ADDR\n\
            sw a0, 0(t0)\n\
            andi t1, s10, 1\n\
            bnez t1, sdb_next_in_even\n\
            li a1, {in1}\n\
            j sdb_next_in_set\n\
            sdb_next_in_even:\n\
            li a1, {in0}\n\
            sdb_next_in_set:\n\
            la t0, SYSDMA_LOCAL_ADDR\n\
            sw a1, 0(t0)\n\
            la t0, SYSDMA_BYTES_ADDR\n\
            li t1, {chunk}\n\
            sw t1, 0(t0)\n\
            la t0, SYSDMA_TRIGGER_ADDR\n\
            li t1, 1\n\
            sw t1, 0(t0)\n\
            sdb_no_next_in:\n\
            # write back the previous round's output (if any)\n\
            beqz s10, sdb_no_writeback\n\
            addi t0, s10, -1\n\
            li t1, {out_bytes}\n\
            mul t1, t0, t1\n\
            lw a0, 20(sp)\n\
            add a0, a0, t1\n\
            la t0, SYSDMA_L2_ADDR\n\
            sw a0, 0(t0)\n\
            andi t1, s10, 1\n\
            bnez t1, sdb_wb_odd\n\
            li a1, {out1}\n\
            j sdb_wb_set\n\
            sdb_wb_odd:\n\
            li a1, {out0}\n\
            sdb_wb_set:\n\
            la t0, SYSDMA_LOCAL_ADDR\n\
            sw a1, 0(t0)\n\
            la t0, SYSDMA_BYTES_ADDR\n\
            li t1, {out_bytes}\n\
            sw t1, 0(t0)\n\
            la t0, SYSDMA_TRIGGER_ADDR\n\
            sw zero, 0(t0)\n\
            sdb_no_writeback:\n\
            sdb_skip_dma:\n",
            wait = legacy_sdma_wait(90),
            chunk = self.chunk_bytes,
            in0 = self.in_bufs[0],
            in1 = self.in_bufs[1],
            out_bytes = self.out_bytes,
            out0 = self.out_bufs[0],
            out1 = self.out_bufs[1],
        )
    }

    fn epilogue(&self, rounds: u32) -> String {
        let last = rounds - 1;
        format!(
            "\
            bnez s9, sdb_skip_final\n\
            {wait}\
            lw a0, 20(sp)\n\
            li t1, {last_off}\n\
            add a0, a0, t1\n\
            la t0, SYSDMA_L2_ADDR\n\
            sw a0, 0(t0)\n\
            la t0, SYSDMA_LOCAL_ADDR\n\
            li a1, {spm}\n\
            sw a1, 0(t0)\n\
            la t0, SYSDMA_BYTES_ADDR\n\
            li t1, {out_bytes}\n\
            sw t1, 0(t0)\n\
            la t0, SYSDMA_TRIGGER_ADDR\n\
            sw zero, 0(t0)\n\
            {wait2}\
            sdb_skip_final:\n",
            wait = legacy_sdma_wait(91),
            wait2 = legacy_sdma_wait(92),
            last_off = last * self.out_bytes,
            spm = self.out_bufs[(last & 1) as usize],
            out_bytes = self.out_bytes,
        )
    }
}

/// The pre-redesign streamed-axpy body, verbatim (both targets; the
/// labels differ by prefix).
fn legacy_axpy_body(inb: u32, outb: u32, blk: &str, tag: &str, done: &str) -> String {
    format!(
        "\
        li a0, {inb}\n\
        li a1, {outb}\n\
        add a0, a0, s8\n\
        add a1, a1, s8\n\
        li a2, ALPHA\n\
        li a3, BLOCKS\n\
        li a4, BLOCK_STRIDE\n\
        .align 8\n\
        {blk}_{tag}:\n\
        lw t4, 0(a0)\n\
        lw t5, 4(a0)\n\
        lw t6, 8(a0)\n\
        lw a6, 12(a0)\n\
        p.mac t4, a2, t4\n\
        p.mac t5, a2, t5\n\
        p.mac t6, a2, t6\n\
        p.mac a6, a2, a6\n\
        sw t4, 0(a1)\n\
        sw t5, 4(a1)\n\
        sw t6, 8(a1)\n\
        sw a6, 12(a1)\n\
        add a0, a0, a4\n\
        add a1, a1, a4\n\
        addi a3, a3, -1\n\
        bnez a3, {blk}_{tag}\n\
        j {done}\n"
    )
}

/// The pre-redesign streamed-matmul round body, verbatim (both targets).
/// Starts right after the buffer-select `{p}_buf_set` stores.
fn legacy_matmul_tile_loop(src: &mut String) {
    let acc = [
        "s0", "s1", "s2", "s3", "s4", "s5", "s6", "s7", "a2", "a3", "a4", "a5", "t4", "t5",
        "t6", "a6",
    ];
    src.push_str(
        "\
        sw t1, 8(sp)\n\
        sw t2, 12(sp)\n\
        sw s9, 0(sp)\n\
        tile_loop:\n\
        lw t0, 0(sp)\n\
        li t1, TOTAL_TILES\n\
        bge t0, t1, tiles_done\n\
        addi t1, t0, NUM_CORES\n\
        sw t1, 0(sp)\n\
        srli t2, t0, LOG_TILES_C\n\
        slli t2, t2, 2\n\
        andi t3, t0, TILES_C_MASK\n\
        slli t3, t3, 2\n\
        # A row pointers from this round's slab\n\
        slli t4, t2, LOG_K_B\n\
        lw t5, 8(sp)\n\
        add a0, t5, t4\n\
        li t6, KBYTES\n\
        add a1, a0, t6\n\
        add gp, a1, t6\n\
        add tp, gp, t6\n\
        la t5, mat_b\n\
        slli t4, t3, 2\n\
        add ra, t5, t4\n\
        slli t4, t2, LOG_N_B\n\
        lw t5, 12(sp)\n\
        add t5, t5, t4\n\
        slli t4, t3, 2\n\
        add t5, t5, t4\n\
        sw t5, 4(sp)\n",
    );
    for r in &acc {
        src.push_str(&format!("li {r}, 0\n"));
    }
    src.push_str(
        "\
        li a7, KDIM\n\
        .align 8\n\
        kloop:\n\
        p.lw t0, 4(a0!)\n\
        p.lw t1, 4(a1!)\n\
        p.lw t2, 4(gp!)\n\
        p.lw t3, 4(tp!)\n\
        lw s8, 0(ra)\n",
    );
    let avals = ["t0", "t1", "t2", "t3"];
    for q in 0..4 {
        if q > 0 {
            src.push_str(&format!("lw s8, {}(ra)\n", 4 * q));
        }
        for r in 0..4 {
            src.push_str(&format!("p.mac {}, {}, s8\n", acc[4 * r + q], avals[r]));
        }
    }
    src.push_str(
        "\
        addi ra, ra, NBYTES\n\
        addi a7, a7, -1\n\
        bnez a7, kloop\n\
        lw t0, 4(sp)\n",
    );
    for r in 0..4 {
        for q in 0..4 {
            src.push_str(&format!("sw {}, {}(t0)\n", acc[4 * r + q], 4 * q));
        }
        if r != 3 {
            src.push_str("addi t0, t0, NBYTES\n");
        }
    }
    src.push_str("j tile_loop\ntiles_done:\n");
}

fn legacy_db_axpy(k: &DbAxpy, cfg: &ClusterConfig) -> (String, HashMap<String, u32>) {
    let rt = RtLayout::new(cfg);
    let words = k.chunk_words(cfg) as u32;
    let in0 = rt.data_base;
    let in1 = in0 + 4 * words;
    let out0 = in1 + 4 * words;
    let out1 = out0 + 4 * words;
    let p = LegacyDbPlumbing {
        chunk_bytes: 4 * words,
        out_bytes: 4 * words,
        in_bufs: [in0, in1],
        out_bufs: [out0, out1],
        l2_in: 0x10_0000,
        l2_out: 0x20_0000,
    };
    let mut sym = HashMap::new();
    rt.add_symbols(&mut sym);
    sym.insert("BLOCKS".into(), (k.per_core / 4) as u32);
    sym.insert("BLOCK_STRIDE".into(), (cfg.num_tiles() * 64) as u32);
    sym.insert("ALPHA".into(), k.alpha);
    let mut src = format!(
        "\
        csrr s9, mhartid\n\
        li s10, 0\n\
        li s11, {rounds}\n\
        # this core's island offset within a chunk\n\
        srli t1, s9, 2\n\
        andi t2, s9, 3\n\
        slli t3, t1, 6\n\
        slli t4, t2, 4\n\
        add s8, t3, t4\n\
        db_round:\n\
        bge s10, s11, db_done\n",
        rounds = k.rounds
    );
    src.push_str(&legacy_trace_marker(crate::trace::REGION_LOAD));
    src.push_str(&p.round_prologue());
    src.push_str(&barrier_asm(80));
    src.push_str(&legacy_trace_marker(crate::trace::REGION_COMPUTE));
    src.push_str("andi t0, s10, 1\nbnez t0, db_odd\n");
    src.push_str(&legacy_axpy_body(p.in_bufs[0], p.out_bufs[0], "blk", "even", "db_compute_done"));
    src.push_str("db_odd:\n");
    src.push_str(&legacy_axpy_body(p.in_bufs[1], p.out_bufs[1], "blk", "odd", "db_compute_done"));
    src.push_str("db_compute_done:\n");
    src.push_str(&legacy_trace_marker(crate::trace::REGION_BARRIER));
    src.push_str(&barrier_asm(81));
    src.push_str("addi s10, s10, 1\nj db_round\ndb_done:\n");
    src.push_str(&legacy_trace_marker(crate::trace::REGION_STORE));
    src.push_str(&p.epilogue(k.rounds as u32));
    src.push_str(&barrier_asm(82));
    src.push_str("halt\n");
    (src, sym)
}

fn legacy_matmul_symbols(
    sym: &mut HashMap<String, u32>,
    a0_buf: u32,
    slab_rows: usize,
    n: usize,
    kdim: usize,
) {
    let tiles_c = n / 4;
    let total_tiles = (slab_rows / 4) * tiles_c;
    sym.insert("mat_b".into(), a0_buf - 4 * (kdim * n) as u32);
    sym.insert("TOTAL_TILES".into(), total_tiles as u32);
    sym.insert("LOG_TILES_C".into(), tiles_c.trailing_zeros());
    sym.insert("TILES_C_MASK".into(), (tiles_c - 1) as u32);
    sym.insert("KBYTES".into(), (kdim * 4) as u32);
    sym.insert("NBYTES".into(), (n * 4) as u32);
    sym.insert("KDIM".into(), kdim as u32);
    sym.insert("LOG_K_B".into(), (kdim * 4).trailing_zeros());
    sym.insert("LOG_N_B".into(), (n * 4).trailing_zeros());
}

fn legacy_db_matmul(k: &DbMatmul, cfg: &ClusterConfig) -> (String, HashMap<String, u32>) {
    let rt = RtLayout::new(cfg);
    let b_words = (k.k * k.n) as u32;
    let a_words = (k.slab_rows * k.k) as u32;
    let c_words = (k.slab_rows * k.n) as u32;
    let b = rt.data_base;
    let a0 = b + 4 * b_words;
    let a1 = a0 + 4 * a_words;
    let c0 = a1 + 4 * a_words;
    let c1 = c0 + 4 * c_words;
    let p = LegacyDbPlumbing {
        chunk_bytes: 4 * a_words,
        out_bytes: 4 * c_words,
        in_bufs: [a0, a1],
        out_bufs: [c0, c1],
        l2_in: 0x10_0000,
        l2_out: 0x40_0000,
    };
    let mut sym = HashMap::new();
    rt.add_symbols(&mut sym);
    legacy_matmul_symbols(&mut sym, p.in_bufs[0], k.slab_rows, k.n, k.k);
    let mut src = format!(
        "\
        addi sp, sp, -16\n\
        csrr s9, mhartid\n\
        li s10, 0\n\
        li s11, {rounds}\n\
        db_round:\n\
        bge s10, s11, db_done\n",
        rounds = k.rounds
    );
    src.push_str(&legacy_trace_marker(crate::trace::REGION_LOAD));
    src.push_str(&p.round_prologue());
    src.push_str(&barrier_asm(80));
    src.push_str(&legacy_trace_marker(crate::trace::REGION_COMPUTE));
    src.push_str(&format!(
        "\
        andi t0, s10, 1\n\
        bnez t0, db_buf_odd\n\
        li t1, {a0}\n\
        li t2, {c0}\n\
        j db_buf_set\n\
        db_buf_odd:\n\
        li t1, {a1}\n\
        li t2, {c1}\n\
        db_buf_set:\n",
        a0 = p.in_bufs[0],
        a1 = p.in_bufs[1],
        c0 = p.out_bufs[0],
        c1 = p.out_bufs[1],
    ));
    legacy_matmul_tile_loop(&mut src);
    src.push_str(&legacy_trace_marker(crate::trace::REGION_BARRIER));
    src.push_str(&barrier_asm(81));
    src.push_str("addi s10, s10, 1\nj db_round\ndb_done:\n");
    src.push_str(&legacy_trace_marker(crate::trace::REGION_STORE));
    src.push_str(&p.epilogue(k.rounds as u32));
    src.push_str(&barrier_asm(82));
    src.push_str("halt\n");
    (src, sym)
}

fn legacy_sys_axpy(k: &SysAxpy, cfg: &SystemConfig) -> (String, HashMap<String, u32>) {
    let rt = RtLayout::new(&cfg.cluster);
    let chunk = 4 * (k.per_core * cfg.cluster.num_cores()) as u32;
    let in0 = rt.data_base;
    let in1 = in0 + chunk;
    let out0 = in1 + chunk;
    let out1 = out0 + chunk;
    let p = LegacySysDbPlumbing {
        chunk_bytes: chunk,
        out_bytes: chunk,
        in_bufs: [in0, in1],
        out_bufs: [out0, out1],
        l2_in: 0x10_0000,
        l2_out: 0x200_0000,
        in_shard_stride: chunk * k.rounds as u32,
        out_shard_stride: chunk * k.rounds as u32,
    };
    let mut sym = HashMap::new();
    rt.add_symbols(&mut sym);
    sym.insert("BLOCKS".into(), (k.per_core / 4) as u32);
    sym.insert("BLOCK_STRIDE".into(), (cfg.cluster.num_tiles() * 64) as u32);
    sym.insert("ALPHA".into(), k.alpha);
    let mut src = p.program_prologue(k.rounds as u32);
    src.push_str(
        "\
        # this core's island offset within a chunk\n\
        srli t1, s9, 2\n\
        andi t2, s9, 3\n\
        slli t3, t1, 6\n\
        slli t4, t2, 4\n\
        add s8, t3, t4\n\
        sdb_round:\n\
        bge s10, s11, sdb_done\n",
    );
    src.push_str(&legacy_trace_marker(crate::trace::REGION_LOAD));
    src.push_str(&p.round_prologue());
    src.push_str(&barrier_asm(80));
    src.push_str(&legacy_trace_marker(crate::trace::REGION_COMPUTE));
    src.push_str("andi t0, s10, 1\nbnez t0, sdb_odd\n");
    src.push_str(&legacy_axpy_body(
        p.in_bufs[0],
        p.out_bufs[0],
        "sblk",
        "even",
        "sdb_compute_done",
    ));
    src.push_str("sdb_odd:\n");
    src.push_str(&legacy_axpy_body(
        p.in_bufs[1],
        p.out_bufs[1],
        "sblk",
        "odd",
        "sdb_compute_done",
    ));
    src.push_str("sdb_compute_done:\n");
    src.push_str(&legacy_trace_marker(crate::trace::REGION_BARRIER));
    src.push_str(&barrier_asm(81));
    src.push_str("addi s10, s10, 1\nj sdb_round\nsdb_done:\n");
    src.push_str(&legacy_trace_marker(crate::trace::REGION_STORE));
    src.push_str(&p.epilogue(k.rounds as u32));
    src.push_str(&barrier_asm(82));
    // The trailing fabric rendezvous every system kernel now carries.
    src.push_str(&legacy_global_barrier(83));
    src.push_str("halt\n");
    (src, sym)
}

fn legacy_sys_matmul(k: &SysMatmul, cfg: &SystemConfig) -> (String, HashMap<String, u32>) {
    let rt = RtLayout::new(&cfg.cluster);
    let b_words = (k.k * k.n) as u32;
    let a_bytes = 4 * (k.slab_rows * k.k) as u32;
    let c_bytes = 4 * (k.slab_rows * k.n) as u32;
    let b = rt.data_base;
    let a0 = b + 4 * b_words;
    let a1 = a0 + a_bytes;
    let c0 = a1 + a_bytes;
    let c1 = c0 + c_bytes;
    let p = LegacySysDbPlumbing {
        chunk_bytes: a_bytes,
        out_bytes: c_bytes,
        in_bufs: [a0, a1],
        out_bufs: [c0, c1],
        l2_in: 0x10_0000,
        l2_out: 0x200_0000,
        in_shard_stride: a_bytes * k.rounds as u32,
        out_shard_stride: c_bytes * k.rounds as u32,
    };
    let mut sym = HashMap::new();
    rt.add_symbols(&mut sym);
    legacy_matmul_symbols(&mut sym, p.in_bufs[0], k.slab_rows, k.n, k.k);
    let mut src = p.program_prologue(k.rounds as u32);
    src.push_str("sdb_round:\nbge s10, s11, sdb_done\n");
    src.push_str(&legacy_trace_marker(crate::trace::REGION_LOAD));
    src.push_str(&p.round_prologue());
    src.push_str(&barrier_asm(80));
    src.push_str(&legacy_trace_marker(crate::trace::REGION_COMPUTE));
    src.push_str(&format!(
        "\
        andi t0, s10, 1\n\
        bnez t0, sdb_buf_odd\n\
        li t1, {a0}\n\
        li t2, {c0}\n\
        j sdb_buf_set\n\
        sdb_buf_odd:\n\
        li t1, {a1}\n\
        li t2, {c1}\n\
        sdb_buf_set:\n",
        a0 = p.in_bufs[0],
        a1 = p.in_bufs[1],
        c0 = p.out_bufs[0],
        c1 = p.out_bufs[1],
    ));
    legacy_matmul_tile_loop(&mut src);
    src.push_str(&legacy_trace_marker(crate::trace::REGION_BARRIER));
    src.push_str(&barrier_asm(81));
    src.push_str("addi s10, s10, 1\nj sdb_round\nsdb_done:\n");
    src.push_str(&legacy_trace_marker(crate::trace::REGION_STORE));
    src.push_str(&p.epilogue(k.rounds as u32));
    src.push_str(&barrier_asm(82));
    // The trailing fabric rendezvous every system kernel now carries.
    src.push_str(&legacy_global_barrier(83));
    src.push_str("halt\n");
    (src, sym)
}

#[test]
fn builder_golden_db_axpy_matches_legacy_string() {
    let cfg = ClusterConfig::minpool();
    let k = DbAxpy::new(32, 3);
    let built = assemble_built(&k, &cfg);
    let (src, sym) = legacy_db_axpy(&k, &cfg);
    let legacy = assemble_legacy(&src, sym, &cfg);
    assert_instruction_identical("db_axpy", &built, &legacy);
}

#[test]
fn builder_golden_db_matmul_matches_legacy_string() {
    let cfg = ClusterConfig::minpool();
    let k = DbMatmul::new(16, 16, 16, 3);
    let built = assemble_built(&k, &cfg);
    let (src, sym) = legacy_db_matmul(&k, &cfg);
    let legacy = assemble_legacy(&src, sym, &cfg);
    assert_instruction_identical("db_matmul", &built, &legacy);
}

#[test]
fn builder_golden_sys_axpy_matches_legacy_string() {
    let cfg = SystemConfig::with_cores(2, 4);
    let k = SysAxpy::new(8, 2);
    let built = assemble_built_system(&k, &cfg);
    let (src, sym) = legacy_sys_axpy(&k, &cfg);
    let legacy = assemble_legacy_system(&src, sym, &cfg);
    assert_instruction_identical("sys_axpy", &built, &legacy);
}

#[test]
fn builder_golden_sys_matmul_matches_legacy_string() {
    let cfg = SystemConfig::with_cores(2, 4);
    let k = SysMatmul::new(8, 8, 8, 2);
    let built = assemble_built_system(&k, &cfg);
    let (src, sym) = legacy_sys_matmul(&k, &cfg);
    let legacy = assemble_legacy_system(&src, sym, &cfg);
    assert_instruction_identical("sys_matmul", &built, &legacy);
}

#[test]
fn builder_golden_trace_marker_text_is_pinned() {
    // The intrinsic's emitted source, pinned verbatim: one region-id
    // store to CTRL_TRACE_MARKER (clobbers t0/t1).
    let mut b = AsmBuilder::new();
    b.trace_marker(crate::trace::REGION_COMPUTE);
    let (src, _) = b.finish();
    assert_eq!(src, legacy_trace_marker(crate::trace::REGION_COMPUTE));
    // And it assembles against the cluster harness symbols.
    let cfg = ClusterConfig::minpool();
    let sym = base_symbols(&cfg);
    let mut full = src;
    full.push_str("halt\n");
    Program::assemble(&full, &sym).expect("trace marker must assemble");
}

#[test]
fn builder_golden_global_barrier_text_is_pinned() {
    // The intrinsic's emitted source, pinned verbatim: two local
    // rendezvous around hart 0's CTRL_GBARRIER pulse + release poll.
    let mut b = AsmBuilder::new();
    b.global_barrier(0);
    let (src, _) = b.finish();
    assert_eq!(src, legacy_global_barrier(0));
    // And it assembles against the system harness symbols.
    let cfg = SystemConfig::with_cores(2, 4);
    let mut sym = system_symbols(&cfg);
    sym.insert("rt_barrier_count".into(), 0x100);
    sym.insert("rt_barrier_epoch".into(), 0x104);
    let mut full = src;
    full.push_str("halt\n");
    Program::assemble(&full, &sym).expect("global barrier must assemble");
}
