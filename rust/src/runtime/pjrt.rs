//! The real PJRT runtime (feature `golden`): loads the AOT-compiled
//! golden models (`artifacts/*.hlo.txt`, produced once by `make
//! artifacts`) and executes them on the XLA CPU client from the rust side
//! — Python never runs at simulation time.
//!
//! The golden models verify the cycle-accurate simulator's results
//! bit-for-bit (both sides compute over wrapping int32), closing the loop
//! between the three layers: Pallas kernel (L1) → jitted JAX graph (L2) →
//! HLO text → this loader (L3).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Locate the artifacts directory: `$MEMPOOL_ARTIFACTS`, or `artifacts/`
/// relative to the crate root (works for `cargo test`/`run` from the
/// workspace).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("MEMPOOL_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in &candidates {
        if c.exists() {
            return c.clone();
        }
    }
    candidates[0].clone()
}

/// True if the artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("matmul.hlo.txt").exists()
}

/// A loaded golden model.
pub struct GoldenModel {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl GoldenModel {
    /// Execute on int32 inputs; returns the flattened int32 outputs of
    /// the (single-element) result tuple.
    pub fn run_i32(&self, inputs: &[Literal]) -> Result<Vec<i32>> {
        let result = self.exe.execute::<Literal>(inputs)?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let out = result.to_tuple1().context("unwrap 1-tuple")?;
        Ok(out.to_vec::<i32>()?)
    }
}

/// The PJRT runtime: one CPU client, executables cached per model.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, GoldenModel>,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        Ok(Runtime {
            client: PjRtClient::cpu().context("create PJRT CPU client")?,
            dir: artifacts_dir(),
            cache: HashMap::new(),
        })
    }

    pub fn with_dir(dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime {
            client: PjRtClient::cpu()?,
            dir: dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) the golden model `name`.
    pub fn load(&mut self, name: &str) -> Result<&GoldenModel> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = HloModuleProto::from_text_file(
                path.to_str().context("artifact path not unicode")?,
            )
            .with_context(|| format!("load HLO text {path:?} (run `make artifacts`?)"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).context("PJRT compile")?;
            self.cache
                .insert(name.to_string(), GoldenModel { exe, name: name.to_string() });
        }
        Ok(&self.cache[name])
    }

    /// Convenience: run model `name` on int32 tensors given as
    /// (data, dims) pairs.
    pub fn run_i32(&mut self, name: &str, inputs: &[(&[i32], &[usize])]) -> Result<Vec<i32>> {
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = Literal::vec1(data);
                if dims.len() > 1 || (dims.len() == 1 && dims[0] != data.len()) || dims.is_empty()
                {
                    let d: Vec<i64> = dims.iter().map(|x| *x as i64).collect();
                    lit.reshape(&d).context("reshape input")
                } else {
                    Ok(lit)
                }
            })
            .collect::<Result<_>>()?;
        self.load(name)?;
        self.cache[name].run_i32(&lits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime_or_skip() -> Option<Runtime> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Runtime::new().expect("PJRT client"))
    }

    #[test]
    fn golden_matmul_executes() {
        let Some(mut rt) = runtime_or_skip() else { return };
        // Shapes must match the registry defaults: (64, 32, 32).
        let (m, n, k) = (64usize, 32usize, 32usize);
        let a: Vec<i32> = (0..m * k).map(|i| (i % 7) as i32 - 3).collect();
        let b: Vec<i32> = (0..k * n).map(|i| (i % 5) as i32 - 2).collect();
        let got = rt
            .run_i32("matmul", &[(&a, &[m, k]), (&b, &[k, n])])
            .expect("execute");
        assert_eq!(got.len(), m * n);
        // Host check.
        for i in [0usize, 17, m * n - 1] {
            let (r, c) = (i / n, i % n);
            let mut acc = 0i32;
            for kk in 0..k {
                acc = acc.wrapping_add(a[r * k + kk].wrapping_mul(b[kk * n + c]));
            }
            assert_eq!(got[i], acc, "C[{r},{c}]");
        }
    }

    #[test]
    fn golden_axpy_executes() {
        let Some(mut rt) = runtime_or_skip() else { return };
        let n = 4096usize;
        let x: Vec<i32> = (0..n).map(|i| i as i32).collect();
        let y: Vec<i32> = (0..n).map(|i| 2 * i as i32).collect();
        let alpha = [3i32];
        let got = rt
            .run_i32("axpy", &[(&alpha, &[]), (&x, &[n]), (&y, &[n])])
            .expect("execute");
        for i in [0usize, 100, n - 1] {
            assert_eq!(got[i], 3 * i as i32 + 2 * i as i32);
        }
    }

    #[test]
    fn golden_dotp_executes() {
        let Some(mut rt) = runtime_or_skip() else { return };
        let n = 4096usize;
        let x = vec![2i32; n];
        let y = vec![3i32; n];
        let got = rt.run_i32("dotp", &[(&x, &[n]), (&y, &[n])]).expect("execute");
        assert_eq!(got, vec![6 * n as i32]);
    }
}
