//! Dependency-free stand-in for the PJRT golden-model runtime, used when
//! the `golden` feature is off. Keeps the public API shape so the CLI,
//! examples, and integration tests compile unchanged; reports the golden
//! models as unavailable so every caller takes its skip path.

use std::path::{Path, PathBuf};

const DISABLED: &str =
    "golden runtime disabled in this build: rebuild with `--features golden` \
     (requires the xla/PJRT toolchain) and run `make artifacts`";

/// Locate the artifacts directory: `$MEMPOOL_ARTIFACTS`, or `artifacts/`
/// relative to the crate root. Kept for tooling parity with the real
/// runtime.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("MEMPOOL_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in &candidates {
        if c.exists() {
            return c.clone();
        }
    }
    candidates[0].clone()
}

/// Always `false`: without the PJRT client the artifacts cannot be
/// executed, so golden comparisons must skip even if the files exist.
pub fn artifacts_available() -> bool {
    false
}

/// Stub golden model; never constructed.
pub struct GoldenModel {
    pub name: String,
}

impl GoldenModel {
    pub fn run_i32(&self, _inputs: &[()]) -> Result<Vec<i32>, String> {
        Err(DISABLED.to_string())
    }
}

/// Stub runtime: construction fails with a actionable message.
pub struct Runtime;

impl Runtime {
    pub fn new() -> Result<Runtime, String> {
        Err(DISABLED.to_string())
    }

    pub fn with_dir(_dir: impl AsRef<Path>) -> Result<Runtime, String> {
        Runtime::new()
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load(&mut self, _name: &str) -> Result<&GoldenModel, String> {
        Err(DISABLED.to_string())
    }

    /// Signature-compatible with the real runtime's convenience entry.
    pub fn run_i32(
        &mut self,
        _name: &str,
        _inputs: &[(&[i32], &[usize])],
    ) -> Result<Vec<i32>, String> {
        Err(DISABLED.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(!artifacts_available());
        let err = Runtime::new().err().expect("stub must not construct");
        assert!(err.contains("golden"), "{err}");
    }
}
