//! The one workload registry: every kernel name exists exactly here,
//! with the targets it supports and its weak-scaled constructor per
//! target. The CLI (`run`, `system`), the sweep runner, and the studies
//! all resolve names through this table, so adding a workload is a
//! single entry (see README "Programming model" for the recipe).

use crate::config::ClusterConfig;
use crate::kernels::apps::{Bfs, HistEq, Raytrace};
use crate::kernels::doublebuf::{DbAxpy, DbMatmul};
use crate::kernels::{Axpy, AxpyBurst, Conv2d, Dct, Dotp, Matmul};
use crate::runtime::{Target, Workload};
use crate::system::{SysAxpy, SysMatmul, SysReduce};

/// Weak-scaled constructor: cores per cluster → boxed workload.
///
/// Constructors that ignore the argument (conv2d, dct, the apps) are
/// still weak-scaled: those workloads size themselves per-core from the
/// `ClusterConfig` at build/setup time, so total work grows with the
/// core count either way.
type Make = fn(usize) -> Box<dyn Workload>;

/// One registry row: a workload name and its per-target constructors.
pub struct WorkloadEntry {
    pub name: &'static str,
    /// Member of the paper's Table 1 suite (the default `run` set).
    pub table1: bool,
    cluster: Option<Make>,
    system: Option<Make>,
}

impl WorkloadEntry {
    fn make_for(&self, target: Target) -> Option<Make> {
        match target {
            Target::Cluster => self.cluster,
            Target::System => self.system,
        }
    }

    pub fn supports(&self, target: Target) -> bool {
        self.make_for(target).is_some()
    }
}

fn c_matmul(cores: usize) -> Box<dyn Workload> {
    Box::new(Matmul::weak_scaled(cores))
}
fn s_matmul(cores: usize) -> Box<dyn Workload> {
    Box::new(SysMatmul::weak_scaled(cores))
}
fn c_conv2d(cores: usize) -> Box<dyn Workload> {
    Box::new(Conv2d::weak_scaled(cores))
}
fn c_dct(cores: usize) -> Box<dyn Workload> {
    Box::new(Dct::weak_scaled(cores))
}
fn c_axpy(cores: usize) -> Box<dyn Workload> {
    Box::new(Axpy::weak_scaled(cores))
}
fn s_axpy(cores: usize) -> Box<dyn Workload> {
    Box::new(SysAxpy::weak_scaled(cores))
}
fn c_dotp(cores: usize) -> Box<dyn Workload> {
    Box::new(Dotp::weak_scaled(cores))
}
fn c_axpy_burst(cores: usize) -> Box<dyn Workload> {
    Box::new(AxpyBurst::weak_scaled(cores))
}
fn s_reduce(cores: usize) -> Box<dyn Workload> {
    Box::new(SysReduce::weak_scaled(cores))
}
fn c_db_matmul(cores: usize) -> Box<dyn Workload> {
    Box::new(DbMatmul::weak_scaled(cores))
}
fn c_db_axpy(cores: usize) -> Box<dyn Workload> {
    Box::new(DbAxpy::weak_scaled(cores))
}
fn c_histeq(_cores: usize) -> Box<dyn Workload> {
    Box::new(HistEq::new())
}
fn c_raytrace(_cores: usize) -> Box<dyn Workload> {
    Box::new(Raytrace::new())
}
fn c_bfs(_cores: usize) -> Box<dyn Workload> {
    Box::new(Bfs::new())
}

/// Every workload, in the paper's presentation order (Table 1 first).
pub static WORKLOADS: &[WorkloadEntry] = &[
    WorkloadEntry { name: "matmul", table1: true, cluster: Some(c_matmul), system: Some(s_matmul) },
    WorkloadEntry { name: "conv2d", table1: true, cluster: Some(c_conv2d), system: None },
    WorkloadEntry { name: "dct", table1: true, cluster: Some(c_dct), system: None },
    WorkloadEntry { name: "axpy", table1: true, cluster: Some(c_axpy), system: Some(s_axpy) },
    WorkloadEntry { name: "dotp", table1: true, cluster: Some(c_dotp), system: None },
    WorkloadEntry {
        name: "axpy_burst",
        table1: false,
        cluster: Some(c_axpy_burst),
        system: None,
    },
    WorkloadEntry { name: "reduce", table1: false, cluster: None, system: Some(s_reduce) },
    WorkloadEntry { name: "db_matmul", table1: false, cluster: Some(c_db_matmul), system: None },
    WorkloadEntry { name: "db_axpy", table1: false, cluster: Some(c_db_axpy), system: None },
    WorkloadEntry { name: "histeq", table1: false, cluster: Some(c_histeq), system: None },
    WorkloadEntry { name: "raytrace", table1: false, cluster: Some(c_raytrace), system: None },
    WorkloadEntry { name: "bfs", table1: false, cluster: Some(c_bfs), system: None },
];

/// Names available on `target`, in registry order.
pub fn workload_names(target: Target) -> Vec<&'static str> {
    WORKLOADS.iter().filter(|e| e.supports(target)).map(|e| e.name).collect()
}

/// All registry names, in registry order.
pub fn all_workload_names() -> Vec<&'static str> {
    WORKLOADS.iter().map(|e| e.name).collect()
}

/// Instantiate a workload by name at its weak-scaled shape for `cores`
/// per cluster, on `target`. Unknown names and unsupported targets both
/// fail with the valid alternatives spelled out.
pub fn workload_by_name(
    name: &str,
    target: Target,
    cores: usize,
) -> Result<Box<dyn Workload>, String> {
    let entry = WORKLOADS
        .iter()
        .find(|e| e.name == name)
        .ok_or_else(|| format!("unknown workload `{name}` (known: {:?})", all_workload_names()))?;
    let make = entry.make_for(target).ok_or_else(|| {
        format!(
            "workload `{name}` has no {}-target variant (available on {}: {:?})",
            target.name(),
            target.name(),
            workload_names(target)
        )
    })?;
    Ok(make(cores))
}

/// The paper's Table 1 suite at its weak-scaled default sizes for `cfg`.
pub fn table1_workloads(cfg: &ClusterConfig) -> Vec<Box<dyn Workload>> {
    let cores = cfg.num_cores();
    WORKLOADS
        .iter()
        .filter(|e| e.table1)
        .map(|e| (e.cluster.expect("Table 1 workloads run on the cluster target"))(cores))
        .collect()
}
