//! Opt-in execution tracing and region profiling.
//!
//! The paper's headline claims ("<2% of execution stalls", the Fig 14
//! per-kernel breakdowns) are *attribution* claims, and whole-run
//! aggregate counters cannot attribute a stall to an instruction, a
//! kernel phase, or a bank. This module adds the attribution layer the
//! real MemPool flow gets from its RTL instruction tracer and
//! Chrome-trace visualizer:
//!
//! - [`CoreTracer`]: a per-core sink fed by `Snitch::step` with the
//!   outcome of every cycle — retired-instruction records (pc,
//!   disassembly, visible writeback) plus stall cycles bucketed by
//!   cause, rolled up per *region*.
//! - Region markers: workloads store a region id to the
//!   `CTRL_TRACE_MARKER` control register (`AsmBuilder::trace_marker`);
//!   the cluster tags the issuing core and the cluster-level phase
//!   roll-up. The well-known ids below map to the canonical kernel
//!   phases.
//! - Conflict heatmaps: per-bank port wins/stalls (including cycles a
//!   core request waited behind a timed system-DMA beat) and
//!   per-interconnect-hop contention, snapshotted at every phase
//!   boundary so conflicts are attributed per region.
//! - Exporters: [`chrome_trace_json`] (the `chrome://tracing` /
//!   Perfetto event-array format; one track per core plus DMA, sync,
//!   and quiescence tracks) and [`regions_json`] (the compact
//!   per-region table the report schema embeds as its optional
//!   `regions` block).
//!
//! **Cycle invisibility is a hard contract**: enabling tracing must not
//! change a single simulated cycle or statistic, on either stepping
//! engine, with or without the quiescence fast path. Everything here is
//! pure observation — the markers are ordinary control-register stores
//! that are emitted *unconditionally* by workloads (so the program, and
//! therefore the timing, is identical whether or not a trace is
//! recorded), and the quiescence skip records every jumped stretch as
//! one explicit "quiescent" span instead of letting it vanish (see
//! `docs/ARCHITECTURE.md`).

use crate::util::json::Json;

/// Well-known region ids (workloads may use any `u32`; these are the
/// canonical phase names the kernels use).
pub const REGION_STARTUP: u32 = 0;
pub const REGION_INIT: u32 = 1;
pub const REGION_LOAD: u32 = 2;
pub const REGION_COMPUTE: u32 = 3;
pub const REGION_STORE: u32 = 4;
pub const REGION_BARRIER: u32 = 5;

/// Human-readable name for a region id.
pub fn region_name(id: u32) -> String {
    match id {
        REGION_STARTUP => "startup".into(),
        REGION_INIT => "init".into(),
        REGION_LOAD => "load".into(),
        REGION_COMPUTE => "compute".into(),
        REGION_STORE => "store".into(),
        REGION_BARRIER => "barrier".into(),
        other => format!("region{other}"),
    }
}

/// What to record. Region roll-ups, heatmaps, and spans are always on
/// once tracing is enabled; the per-instruction stream is opt-in on top
/// (it is by far the largest part of a trace).
#[derive(Debug, Clone, Copy, Default)]
pub struct TraceConfig {
    /// Record one [`InstrRecord`] per issued instruction.
    pub instr: bool,
}

/// Per-region cycle accounting: the same buckets `CoreStats` books,
/// windowed between two markers. Summed over all windows of all cores
/// these must reproduce the whole-run counters exactly — the
/// cross-check the trace tests pin.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RegionCounters {
    pub cycles: u64,
    pub issued_compute: u64,
    pub issued_control: u64,
    pub stall_ifetch: u64,
    pub stall_raw: u64,
    pub stall_lsu: u64,
    pub sleep_cycles: u64,
    pub halted_cycles: u64,
}

impl RegionCounters {
    pub fn add(&mut self, o: &RegionCounters) {
        self.cycles += o.cycles;
        self.issued_compute += o.issued_compute;
        self.issued_control += o.issued_control;
        self.stall_ifetch += o.stall_ifetch;
        self.stall_raw += o.stall_raw;
        self.stall_lsu += o.stall_lsu;
        self.sleep_cycles += o.sleep_cycles;
        self.halted_cycles += o.halted_cycles;
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("cycles", self.cycles.into());
        o.set("issued_compute", self.issued_compute.into());
        o.set("issued_control", self.issued_control.into());
        o.set("stall_ifetch", self.stall_ifetch.into());
        o.set("stall_raw", self.stall_raw.into());
        o.set("stall_lsu", self.stall_lsu.into());
        o.set("sleep_cycles", self.sleep_cycles.into());
        o.set("halted_cycles", self.halted_cycles.into());
        o
    }
}

/// One core's residence in one region: `[start, end)` in cycles.
#[derive(Debug, Clone)]
pub struct RegionWindow {
    pub region: u32,
    pub start: u64,
    pub end: u64,
    pub counters: RegionCounters,
}

/// One issued instruction (the risclet-style `Effects` record): where,
/// what, and the register writeback if it is architecturally visible in
/// the issue cycle (loads and IPU results retire later through the
/// scoreboard and are recorded without a writeback value).
#[derive(Debug, Clone)]
pub struct InstrRecord {
    pub cycle: u64,
    /// Program counter as an instruction index.
    pub pc: u32,
    /// Disassembly text.
    pub text: String,
    /// `(abi register name, value)` when visible at issue.
    pub wb: Option<(&'static str, u32)>,
}

/// Per-core trace sink. Owned by the core (behind an `Option<Box<..>>`
/// so the disabled path is a single pointer test) and harvested into
/// the cluster's [`TraceBook`] when the run ends.
#[derive(Debug, Clone, Default)]
pub struct CoreTracer {
    /// Global core id.
    pub core: u32,
    record_instrs: bool,
    region: u32,
    window_start: u64,
    cur: RegionCounters,
    pub windows: Vec<RegionWindow>,
    pub instrs: Vec<InstrRecord>,
}

impl CoreTracer {
    pub fn new(core: u32, cfg: TraceConfig) -> Self {
        CoreTracer { core, record_instrs: cfg.instr, ..Default::default() }
    }

    pub fn record_instrs(&self) -> bool {
        self.record_instrs
    }

    /// Current region id.
    pub fn region(&self) -> u32 {
        self.region
    }

    /// Book one stepped cycle into the current window's bucket. The
    /// caller (the core) has already classified the outcome.
    pub fn bump(&mut self, bucket: Bucket) {
        self.cur.cycles += 1;
        match bucket {
            Bucket::Compute => self.cur.issued_compute += 1,
            Bucket::Control => self.cur.issued_control += 1,
            Bucket::IFetch => self.cur.stall_ifetch += 1,
            Bucket::Raw => self.cur.stall_raw += 1,
            Bucket::Lsu => self.cur.stall_lsu += 1,
            Bucket::Sleep => self.cur.sleep_cycles += 1,
            Bucket::Halted => self.cur.halted_cycles += 1,
        }
    }

    pub fn push_instr(&mut self, rec: InstrRecord) {
        self.instrs.push(rec);
    }

    /// Mirror of `Snitch::age_quiet`: `delta` skipped cycles, all in
    /// the halted or sleep bucket.
    pub fn age_quiet(&mut self, delta: u64, halted: bool) {
        self.cur.cycles += delta;
        if halted {
            self.cur.halted_cycles += delta;
        } else {
            self.cur.sleep_cycles += delta;
        }
    }

    /// A region marker reached this core at cycle `now`: close the
    /// current window and open the next (cycle `now` itself is counted
    /// in the *new* region — marker effects apply before cores step, in
    /// both engines).
    pub fn set_region(&mut self, now: u64, region: u32) {
        self.close_window(now);
        self.region = region;
    }

    /// Close the last open window at `end` (end of run).
    pub fn finalize(&mut self, end: u64) {
        self.close_window(end);
    }

    fn close_window(&mut self, end: u64) {
        if self.cur != RegionCounters::default() || end > self.window_start {
            self.windows.push(RegionWindow {
                region: self.region,
                start: self.window_start,
                end,
                counters: self.cur,
            });
        }
        self.cur = RegionCounters::default();
        self.window_start = end;
    }
}

/// How one stepped cycle should be booked (mirrors the `StepOutcome` ×
/// instruction-class split `CoreStats` uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bucket {
    Compute,
    Control,
    IFetch,
    Raw,
    Lsu,
    Sleep,
    Halted,
}

/// Per-tile bank-port heat counters, bumped by `Tile::serve_banks`.
#[derive(Debug, Clone, Default)]
pub struct TileHeat {
    /// Requests served per bank (the port "wins").
    pub wins: Vec<u64>,
    /// Queue-wait attributed per bank: each served cycle adds the
    /// number of requests left waiting on that bank's queue (plus the
    /// whole queue depth when a system-DMA beat holds the port).
    pub stalls: Vec<u64>,
    /// Timed system-DMA beats that occupied each bank's port.
    pub dma_beats: Vec<u64>,
}

impl TileHeat {
    pub fn new(banks: usize) -> Self {
        TileHeat { wins: vec![0; banks], stalls: vec![0; banks], dma_beats: vec![0; banks] }
    }
}

/// A cumulative-counter snapshot (flattened over `tile × bank`, plus
/// the interconnect hop counters); phase windows are deltas between
/// consecutive snapshots.
#[derive(Debug, Clone, Default)]
pub struct HeatSnapshot {
    pub wins: Vec<u64>,
    pub stalls: Vec<u64>,
    pub dma_beats: Vec<u64>,
    pub hops: Vec<(String, u64)>,
}

/// Cluster-level phase window: the heat accumulated while the cluster
/// was in `region` (the id of the most recent marker from any core).
#[derive(Debug, Clone)]
pub struct PhaseWindow {
    pub region: u32,
    pub start: u64,
    pub end: u64,
    /// Per-bank deltas, flattened `tile × bank`.
    pub wins: Vec<u64>,
    pub stalls: Vec<u64>,
    pub dma_beats: Vec<u64>,
    /// Per-hop contention deltas (label → conflict count).
    pub hops: Vec<(String, u64)>,
}

/// A region marker observed by the cluster.
#[derive(Debug, Clone, Copy)]
pub struct MarkerEvent {
    pub at: u64,
    pub core: u32,
    pub region: u32,
}

/// Everything one cluster recorded during a traced run. Mutated only
/// from serial contexts (control-register effects, the quiescence
/// skip, DMA triggers), so both stepping engines fill it identically.
#[derive(Debug, Clone, Default)]
pub struct TraceBook {
    pub cluster_id: usize,
    pub num_cores: usize,
    pub markers: Vec<MarkerEvent>,
    /// Harvested per-core tracers (windows + instruction records).
    pub cores: Vec<CoreTracer>,
    /// Cluster-level per-region heat windows.
    pub phases: Vec<PhaseWindow>,
    /// Quiescence-skipped stretches `[from, to)` — every fast-path jump
    /// appears here as one explicit span.
    pub quiescent: Vec<(u64, u64)>,
    /// Cluster-local DMA transfers `[trigger, done)`.
    pub dma: Vec<(u64, u64)>,
    /// System-DMA transfers `[start, done)` serviced for this cluster.
    pub sysdma: Vec<(u64, u64)>,
    /// Global-barrier waits `[arrive, release)`.
    pub gbarrier: Vec<(u64, u64)>,
    // Live phase state, maintained by the cluster.
    cluster_region: u32,
    phase_start: u64,
    last_snap: HeatSnapshot,
}

impl TraceBook {
    pub fn new(cluster_id: usize, num_cores: usize) -> Self {
        TraceBook { cluster_id, num_cores, ..Default::default() }
    }

    pub fn cluster_region(&self) -> u32 {
        self.cluster_region
    }

    /// Close the running phase window at `now` against a fresh counter
    /// snapshot and enter `region`.
    pub fn phase_boundary(&mut self, now: u64, region: u32, snap: HeatSnapshot) {
        let sub = |cur: &[u64], old: &[u64]| -> Vec<u64> {
            cur.iter()
                .enumerate()
                .map(|(i, v)| v - old.get(i).copied().unwrap_or(0))
                .collect()
        };
        let hops = snap
            .hops
            .iter()
            .map(|(label, v)| {
                let old = self
                    .last_snap
                    .hops
                    .iter()
                    .find(|(l, _)| l == label)
                    .map(|(_, o)| *o)
                    .unwrap_or(0);
                (label.clone(), v - old)
            })
            .collect();
        if now > self.phase_start {
            self.phases.push(PhaseWindow {
                region: self.cluster_region,
                start: self.phase_start,
                end: now,
                wins: sub(&snap.wins, &self.last_snap.wins),
                stalls: sub(&snap.stalls, &self.last_snap.stalls),
                dma_beats: sub(&snap.dma_beats, &self.last_snap.dma_beats),
                hops,
            });
        }
        self.cluster_region = region;
        self.phase_start = now;
        self.last_snap = snap;
    }
}

/// Aggregate a set of books into the per-region table: one row per
/// region id, counters summed over every window of every core of every
/// cluster, heat summed over every phase window. This is the `regions`
/// block of the v2 report schema.
pub fn regions_json(books: &[TraceBook]) -> Json {
    let mut ids: Vec<u32> = Vec::new();
    for b in books {
        for c in &b.cores {
            for w in &c.windows {
                if !ids.contains(&w.region) {
                    ids.push(w.region);
                }
            }
        }
        for p in &b.phases {
            if !ids.contains(&p.region) {
                ids.push(p.region);
            }
        }
    }
    ids.sort_unstable();
    let mut rows = Vec::new();
    for id in ids {
        let mut counters = RegionCounters::default();
        let mut windows = 0u64;
        for b in books {
            for c in &b.cores {
                for w in &c.windows {
                    if w.region == id {
                        counters.add(&w.counters);
                        windows += 1;
                    }
                }
            }
        }
        let (mut wins, mut stalls, mut beats) = (0u64, 0u64, 0u64);
        let mut hops: Vec<(String, u64)> = Vec::new();
        for b in books {
            for p in &b.phases {
                if p.region != id {
                    continue;
                }
                wins += p.wins.iter().sum::<u64>();
                stalls += p.stalls.iter().sum::<u64>();
                beats += p.dma_beats.iter().sum::<u64>();
                for (label, v) in &p.hops {
                    match hops.iter_mut().find(|(l, _)| l == label) {
                        Some((_, t)) => *t += v,
                        None => hops.push((label.clone(), *v)),
                    }
                }
            }
        }
        let mut row = Json::obj();
        row.set("region", u64::from(id).into());
        row.set("name", region_name(id).into());
        row.set("windows", windows.into());
        row.set("counters", counters.to_json());
        let mut heat = Json::obj();
        heat.set("bank_wins", wins.into());
        heat.set("bank_stall_cycles", stalls.into());
        heat.set("sysdma_beats", beats.into());
        let mut hj = Json::obj();
        for (label, v) in hops {
            hj.set(&label, v.into());
        }
        heat.set("hop_conflicts", hj);
        row.set("heat", heat);
        rows.push(row);
    }
    Json::Arr(rows)
}

/// Export books as a Chrome trace-event document (the
/// `chrome://tracing` / Perfetto JSON array format). One process per
/// cluster; one thread per core carrying its region spans (plus the
/// instruction stream when recorded), then a `dma` track, a `sync`
/// track (global-barrier waits), and a `quiescent` track where every
/// fast-path jump is one explicit span. `ts`/`dur` are in simulated
/// cycles (`displayTimeUnit` maps one cycle to one nanosecond).
pub fn chrome_trace_json(books: &[TraceBook]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let meta = |name: &str, pid: usize, tid: usize, value: &str| -> Json {
        let mut e = Json::obj();
        e.set("name", name.into());
        e.set("ph", "M".into());
        e.set("ts", 0u64.into());
        e.set("pid", pid.into());
        e.set("tid", tid.into());
        let mut args = Json::obj();
        args.set("name", value.into());
        e.set("args", args);
        e
    };
    let span = |name: String, pid: usize, tid: usize, start: u64, end: u64, args: Option<Json>| {
        let mut e = Json::obj();
        e.set("name", name.into());
        e.set("ph", "X".into());
        e.set("ts", start.into());
        e.set("dur", (end.saturating_sub(start)).into());
        e.set("pid", pid.into());
        e.set("tid", tid.into());
        if let Some(a) = args {
            e.set("args", a);
        }
        e
    };
    for book in books {
        let pid = book.cluster_id;
        events.push(meta("process_name", pid, 0, &format!("cluster{pid}")));
        let dma_tid = book.num_cores;
        let sync_tid = book.num_cores + 1;
        let quiet_tid = book.num_cores + 2;
        for (tid, core) in book.cores.iter().enumerate() {
            events.push(meta("thread_name", pid, tid, &format!("core{}", core.core)));
            for w in &core.windows {
                events.push(span(
                    region_name(w.region),
                    pid,
                    tid,
                    w.start,
                    w.end,
                    Some(w.counters.to_json()),
                ));
            }
            for rec in &core.instrs {
                let mut args = Json::obj();
                args.set("pc", u64::from(rec.pc).into());
                if let Some((rd, v)) = rec.wb {
                    args.set("wb", format!("{rd}={v:#x}").into());
                }
                events.push(span(rec.text.clone(), pid, tid, rec.cycle, rec.cycle + 1, Some(args)));
            }
        }
        for m in &book.markers {
            let mut e = Json::obj();
            e.set("name", format!("marker:{}", region_name(m.region)).into());
            e.set("ph", "i".into());
            e.set("ts", m.at.into());
            e.set("pid", pid.into());
            e.set("tid", (m.core as usize % book.num_cores.max(1)).into());
            e.set("s", "t".into());
            events.push(e);
        }
        events.push(meta("thread_name", pid, dma_tid, "dma"));
        events.push(meta("thread_name", pid, sync_tid, "sync"));
        events.push(meta("thread_name", pid, quiet_tid, "quiescent"));
        for &(a, b) in &book.dma {
            events.push(span("dma".into(), pid, dma_tid, a, b, None));
        }
        for &(a, b) in &book.sysdma {
            events.push(span("sysdma".into(), pid, dma_tid, a, b, None));
        }
        for &(a, b) in &book.gbarrier {
            events.push(span("gbarrier".into(), pid, sync_tid, a, b, None));
        }
        for &(a, b) in &book.quiescent {
            events.push(span("quiescent".into(), pid, quiet_tid, a, b, None));
        }
    }
    // The validator (and trace viewers' streaming parsers) want
    // monotonic timestamps.
    events.sort_by_key(|e| e.get("ts").and_then(|t| t.as_u64()).unwrap_or(0));
    let mut doc = Json::obj();
    doc.set("schema", "mempool-trace".into());
    doc.set("version", 1u64.into());
    doc.set("displayTimeUnit", "ns".into());
    doc.set("traceEvents", Json::Arr(events));
    doc
}

/// Structural validation of a Chrome-trace document: `traceEvents` is
/// present, every event carries `name`/`ph`/`ts`/`pid`/`tid`, complete
/// (`X`) events carry `dur`, and timestamps are monotonically
/// non-decreasing. This is what `mempool trace` runs before writing
/// and what the CI trace-smoke job gates on.
pub fn validate_chrome_trace(doc: &Json) -> Result<(), String> {
    let events = doc
        .get("traceEvents")
        .ok_or("missing traceEvents")?
        .as_array()
        .ok_or("traceEvents is not an array")?;
    let mut last_ts = 0u64;
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i}: missing ph"))?
            .to_string();
        for field in ["name", "ts", "pid", "tid"] {
            if e.get(field).is_none() {
                return Err(format!("event {i}: missing {field}"));
            }
        }
        let ts = e
            .get("ts")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| format!("event {i}: ts is not a non-negative integer"))?;
        if ts < last_ts {
            return Err(format!("event {i}: ts {ts} < previous {last_ts} (not monotonic)"));
        }
        last_ts = ts;
        if ph == "X" && e.get("dur").and_then(|v| v.as_u64()).is_none() {
            return Err(format!("event {i}: complete event without integer dur"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn book_with_one_core() -> TraceBook {
        let mut tr = CoreTracer::new(0, TraceConfig { instr: true });
        tr.bump(Bucket::Control);
        tr.bump(Bucket::Compute);
        tr.set_region(2, REGION_COMPUTE);
        tr.bump(Bucket::Compute);
        tr.bump(Bucket::Raw);
        tr.push_instr(InstrRecord { cycle: 2, pc: 7, text: "mac t0, t1, t2".into(), wb: None });
        tr.finalize(4);
        let mut book = TraceBook::new(0, 1);
        book.markers.push(MarkerEvent { at: 2, core: 0, region: REGION_COMPUTE });
        book.quiescent.push((4, 9));
        book.phase_boundary(
            2,
            REGION_COMPUTE,
            HeatSnapshot { wins: vec![3], stalls: vec![1], dma_beats: vec![0], hops: vec![] },
        );
        book.phase_boundary(
            9,
            REGION_COMPUTE,
            HeatSnapshot { wins: vec![5], stalls: vec![1], dma_beats: vec![0], hops: vec![] },
        );
        book.cores.push(tr);
        book
    }

    #[test]
    fn windows_partition_cycles_exactly() {
        let book = book_with_one_core();
        let total: u64 = book.cores[0].windows.iter().map(|w| w.counters.cycles).sum();
        assert_eq!(total, 4);
        assert_eq!(book.cores[0].windows.len(), 2);
        assert_eq!(book.cores[0].windows[0].region, REGION_STARTUP);
        assert_eq!(book.cores[0].windows[1].region, REGION_COMPUTE);
        assert_eq!(book.cores[0].windows[1].counters.stall_raw, 1);
    }

    #[test]
    fn phase_windows_are_deltas() {
        let book = book_with_one_core();
        assert_eq!(book.phases.len(), 2);
        assert_eq!(book.phases[0].wins, vec![3]);
        assert_eq!(book.phases[1].wins, vec![2]);
        assert_eq!(book.phases[1].stalls, vec![0]);
    }

    #[test]
    fn chrome_export_validates_and_contains_quiescent_span() {
        let doc = chrome_trace_json(&[book_with_one_core()]);
        validate_chrome_trace(&doc).expect("structurally valid");
        let events = doc.get("traceEvents").unwrap().as_array().unwrap();
        let quiet = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("quiescent"))
            .expect("the skipped stretch must appear as one explicit span");
        assert_eq!(quiet.get("ts").unwrap().as_u64(), Some(4));
        assert_eq!(quiet.get("dur").unwrap().as_u64(), Some(5));
    }

    #[test]
    fn validator_rejects_non_monotonic_timestamps() {
        let good = chrome_trace_json(&[book_with_one_core()]);
        let mut events = good.get("traceEvents").unwrap().as_array().unwrap().to_vec();
        events.reverse();
        let mut doc = Json::obj();
        doc.set("traceEvents", Json::Arr(events));
        assert!(validate_chrome_trace(&doc).is_err());
    }

    #[test]
    fn regions_table_aggregates_counters_and_heat() {
        let book = book_with_one_core();
        let table = regions_json(&[book]);
        let rows = table.as_array().unwrap();
        assert_eq!(rows.len(), 2);
        let compute = &rows[1];
        assert_eq!(compute.get("name").unwrap().as_str(), Some("compute"));
        let counters = compute.get("counters").unwrap();
        assert_eq!(counters.get("cycles").unwrap().as_u64(), Some(2));
        let heat = compute.get("heat").unwrap();
        assert_eq!(heat.get("bank_wins").unwrap().as_u64(), Some(2));
    }
}
