//! Instruction cache hierarchy (paper §4).
//!
//! Each core owns a tiny private L0 cache (fully associative, with a
//! next-line/backward-branch prefetcher); each tile shares a set-associative
//! L1 instruction cache whose refill logic coalesces requests and responds
//! to all L0s in parallel. The six configurations the paper evaluates
//! (Baseline, 2-Way, L1-Tag Latch, L1-All Latch, L1-Tag+L0 Latch, Serial L1)
//! are expressible via `ICacheConfig` and differ in timing (serial lookup
//! adds a pipeline stage) and in the event counters that feed the energy
//! model (SRAM vs latch tag/data banks, ways read per lookup).

mod config;
mod l0;
mod l1;
mod tile;

pub use config::{ICacheConfig, MemKind};
pub use l0::L0Cache;
pub use l1::L1ICache;
pub use tile::{FetchResult, FixedLatencyPort, RefillPort, TileICache};

#[cfg(test)]
mod tests;
