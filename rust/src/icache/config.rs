//! Instruction cache configuration: the six architectures of paper §4.1.

/// Physical implementation of a tag/data bank — drives the energy model
/// (SRAM macros vs latch-based standard-cell memories vs registers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemKind {
    Sram,
    Latch,
    Register,
}

/// Instruction cache configuration for one tile.
#[derive(Debug, Clone, Copy)]
pub struct ICacheConfig {
    /// L0 lines per core (fully associative).
    pub l0_lines: usize,
    /// Words (instructions) per cache line, shared by L0 and L1.
    /// Baseline: 4 (128-bit); 2-Way onwards: 8 (256-bit).
    pub line_words: usize,
    /// Shared L1 capacity in bytes (2 KiB in all configs).
    pub l1_bytes: usize,
    /// L1 associativity (Baseline: 4; 2-Way onwards: 2).
    pub l1_ways: usize,
    /// Serial tag-then-data lookup (final config): +1 cycle L1 hit
    /// latency, but only one data way read per hit.
    pub serial_lookup: bool,
    /// Enable the L0 next-line / backward-branch prefetcher.
    pub prefetch: bool,
    /// Implementation of the L0 storage (registers in the baseline,
    /// latches in the final config).
    pub l0_kind: MemKind,
    /// Implementation of the L1 tag banks.
    pub l1_tag_kind: MemKind,
    /// Implementation of the L1 data banks.
    pub l1_data_kind: MemKind,
    /// Area of the tile's cache in kGE, from paper §4.1, for reports.
    pub area_kge: f64,
    /// Human-readable name of the configuration.
    pub name: &'static str,
}

impl ICacheConfig {
    /// Paper "Baseline" (149 kGE): 4×128-bit register L0, 2 KiB 4-way L1,
    /// parallel lookup, SRAM tags and data.
    pub fn baseline() -> Self {
        ICacheConfig {
            l0_lines: 4,
            line_words: 4,
            l1_bytes: 2048,
            l1_ways: 4,
            serial_lookup: false,
            prefetch: true,
            l0_kind: MemKind::Register,
            l1_tag_kind: MemKind::Sram,
            l1_data_kind: MemKind::Sram,
            area_kge: 149.0,
            name: "Baseline",
        }
    }

    /// Paper "2-Way" (163 kGE): 256-bit lines (doubled L0 capacity),
    /// 2-way L1.
    pub fn two_way() -> Self {
        ICacheConfig {
            line_words: 8,
            l1_ways: 2,
            area_kge: 163.0,
            name: "2-Way",
            ..ICacheConfig::baseline()
        }
    }

    /// Paper "L1-Tag Latch" (161 kGE): latch-based L1 tags.
    pub fn l1_tag_latch() -> Self {
        ICacheConfig {
            l1_tag_kind: MemKind::Latch,
            area_kge: 161.0,
            name: "L1-Tag Latch",
            ..ICacheConfig::two_way()
        }
    }

    /// Paper "L1-All Latch" (217 kGE): latch-based L1 tags *and* data
    /// (discarded for area).
    pub fn l1_all_latch() -> Self {
        ICacheConfig {
            l1_data_kind: MemKind::Latch,
            area_kge: 217.0,
            name: "L1-All Latch",
            ..ICacheConfig::l1_tag_latch()
        }
    }

    /// Paper "L1-Tag+L0 Latch" (153 kGE): latch L0 instead of latch L1 data.
    pub fn l1_tag_l0_latch() -> Self {
        ICacheConfig {
            l0_kind: MemKind::Latch,
            area_kge: 153.0,
            name: "L1-Tag+L0 Latch",
            ..ICacheConfig::l1_tag_latch()
        }
    }

    /// Paper "Serial L1" (123 kGE): serial tag-then-data lookup, merged
    /// data ways. This is the final, shipped configuration.
    pub fn serial_l1() -> Self {
        ICacheConfig {
            serial_lookup: true,
            area_kge: 123.0,
            name: "Serial L1",
            ..ICacheConfig::l1_tag_l0_latch()
        }
    }

    /// Alias for the final optimized configuration (used by default).
    pub fn final_optimized() -> Self {
        ICacheConfig::serial_l1()
    }

    /// All six configurations in the paper's optimization order.
    pub fn all_paper_configs() -> Vec<ICacheConfig> {
        vec![
            ICacheConfig::baseline(),
            ICacheConfig::two_way(),
            ICacheConfig::l1_tag_latch(),
            ICacheConfig::l1_all_latch(),
            ICacheConfig::l1_tag_l0_latch(),
            ICacheConfig::serial_l1(),
        ]
    }

    /// L0 capacity in instructions.
    pub fn l0_instrs(&self) -> usize {
        self.l0_lines * self.line_words
    }

    /// L1 sets.
    pub fn l1_sets(&self) -> usize {
        self.l1_bytes / (self.line_words * 4 * self.l1_ways)
    }

    /// Line size in bytes.
    pub fn line_bytes(&self) -> usize {
        self.line_words * 4
    }

    /// L1 hit latency in cycles (parallel: 1, serial: 2; the prefetcher
    /// hides this during straight-line execution).
    pub fn l1_hit_latency(&self) -> u64 {
        if self.serial_lookup {
            2
        } else {
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometries() {
        let b = ICacheConfig::baseline();
        assert_eq!(b.l0_instrs(), 16);
        assert_eq!(b.l1_sets(), 2048 / (16 * 4)); // 32 sets
        let t = ICacheConfig::two_way();
        assert_eq!(t.l0_instrs(), 32);
        assert_eq!(t.l1_sets(), 2048 / (32 * 2)); // 32 sets
        assert_eq!(t.l1_bytes, b.l1_bytes, "L1 capacity stays constant");
        let s = ICacheConfig::serial_l1();
        assert!(s.serial_lookup);
        assert_eq!(s.l1_hit_latency(), 2);
        assert_eq!(s.l0_kind, MemKind::Latch);
        assert_eq!(s.l1_tag_kind, MemKind::Latch);
        assert_eq!(s.l1_data_kind, MemKind::Sram);
    }

    #[test]
    fn six_configs() {
        let all = ICacheConfig::all_paper_configs();
        assert_eq!(all.len(), 6);
        // Areas match §4.1.
        let areas: Vec<f64> = all.iter().map(|c| c.area_kge).collect();
        assert_eq!(areas, vec![149.0, 163.0, 161.0, 217.0, 153.0, 123.0]);
    }
}
