//! Per-tile instruction cache orchestration: N private L0 caches sharing
//! one L1 with a single lookup port (1 request/cycle — the paper notes four
//! L0s refilling every four instructions fully utilize the L1 interface),
//! refill coalescing, and prefetch.

use std::collections::VecDeque;

use super::config::ICacheConfig;
use super::l0::{predicted_next_line, L0Cache};
use super::l1::L1ICache;
use crate::isa::Program;

/// Anything that can serve L1 refills (the hierarchical AXI interconnect
/// with its RO cache in the full cluster; a fixed-latency mock in tests).
/// Returns the cycle at which the read data arrives at the tile.
pub trait RefillPort {
    fn read(&mut self, addr: u32, bytes: usize, now: u64) -> u64;
}

/// Fixed-latency refill port for unit tests.
pub struct FixedLatencyPort(pub u64);

impl RefillPort for FixedLatencyPort {
    fn read(&mut self, _addr: u32, _bytes: usize, now: u64) -> u64 {
        now + self.0
    }
}

/// Result of a fetch attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchResult {
    /// Instruction available this cycle.
    Ready,
    /// L0 miss in flight — the core stalls (counted as an I$ stall).
    Stall,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReqKind {
    Demand { core: u8 },
    Prefetch { core: u8 },
}

/// A pending delivery to L0(s): either an L1 hit in its lookup pipeline or
/// an AXI refill in flight.
#[derive(Debug, Clone)]
struct PendingFill {
    line_addr: u32,
    ready_at: u64,
    /// Cores whose L0 receives the line (bitmask).
    waiters: u32,
    /// Fill the L1 on completion (true for AXI refills).
    fill_l1: bool,
}

/// The tile's full instruction cache: per-core L0s + shared L1 + refill
/// machinery.
pub struct TileICache {
    pub cfg: ICacheConfig,
    pub l0: Vec<L0Cache>,
    pub l1: L1ICache,
    line_bytes: u32,
    /// Demand line each stalled core is waiting for.
    pending_demand: Vec<Option<u32>>,
    /// Requests waiting for the single L1 lookup port.
    queue: VecDeque<(u32, ReqKind)>,
    fills: Vec<PendingFill>,
    /// Stat: cycles the L1 lookup port was busy (utilization).
    pub l1_port_busy: u64,
}

impl TileICache {
    pub fn new(cfg: ICacheConfig, cores: usize) -> Self {
        TileICache {
            cfg,
            l0: (0..cores).map(|_| L0Cache::new(cfg.l0_lines)).collect(),
            l1: L1ICache::new(&cfg),
            line_bytes: cfg.line_bytes() as u32,
            pending_demand: vec![None; cores],
            queue: VecDeque::new(),
            fills: Vec::new(),
            l1_port_busy: 0,
        }
    }

    fn line_of(&self, addr: u32) -> u32 {
        addr & !(self.line_bytes - 1)
    }

    fn is_requested(&self, line: u32) -> bool {
        self.queue.iter().any(|(l, _)| *l == line)
            || self.fills.iter().any(|f| f.line_addr == line)
    }

    /// Attempt to fetch the instruction at byte address `addr` for `core`.
    pub fn fetch(&mut self, core: usize, addr: u32, program: &Program) -> FetchResult {
        let line = self.line_of(addr);
        if let Some(pending) = self.pending_demand[core] {
            if pending == line {
                return FetchResult::Stall; // already waiting on it
            }
            // The wait was for a different line (cannot normally happen —
            // a stalled core does not move its PC), clear it.
            self.pending_demand[core] = None;
        }
        let (hit, new_line) = self.l0[core].access(line);
        if hit {
            if new_line && self.cfg.prefetch {
                self.issue_prefetch(core, line, program);
            }
            FetchResult::Ready
        } else {
            self.pending_demand[core] = Some(line);
            // Coalesce with an in-flight fill if one exists.
            if let Some(f) = self.fills.iter_mut().find(|f| f.line_addr == line) {
                f.waiters |= 1 << core;
            } else if let Some(pos) = self.queue.iter().position(|(l, _)| *l == line) {
                // Upgrade a queued prefetch to demand priority by leaving it
                // queued; the waiter resolution happens via pending_demand.
                let _ = pos;
            } else {
                self.queue.push_back((line, ReqKind::Demand { core: core as u8 }));
            }
            FetchResult::Stall
        }
    }

    fn issue_prefetch(&mut self, core: usize, line: u32, program: &Program) {
        if let Some(next) = predicted_next_line(program, line, self.line_bytes) {
            if !self.l0[core].contains(next) && !self.is_requested(next) {
                self.l0[core].prefetches += 1;
                self.queue.push_back((next, ReqKind::Prefetch { core: core as u8 }));
            }
        }
    }

    /// Advance one cycle: complete fills, then serve one L1 lookup.
    pub fn step(&mut self, now: u64, port: &mut dyn RefillPort) {
        if let Some((line, bytes)) = self.step_deferred(now) {
            let done = port.read(line, bytes, now);
            self.resolve_refill(line, done);
        }
    }

    /// Tile-local part of [`step`]: complete due fills and serve one L1
    /// lookup, but *defer* any AXI refill — the returned `(line, bytes)`
    /// request must be resolved with [`resolve_refill`] later in the same
    /// cycle. Used by the parallel backend, whose tile-local phase may not
    /// touch the shared AXI tree.
    pub fn step_deferred(&mut self, now: u64) -> Option<(u32, usize)> {
        // 1. Complete due fills: install into L1 (refills) and waiter L0s.
        //    (An unresolved refill has `ready_at == u64::MAX` and can never
        //    complete before it is resolved.)
        let mut i = 0;
        while i < self.fills.len() {
            if self.fills[i].ready_at <= now {
                let f = self.fills.swap_remove(i);
                if f.fill_l1 {
                    self.l1.fill(f.line_addr);
                }
                for core in 0..self.l0.len() {
                    if f.waiters & (1 << core) != 0 {
                        self.l0[core].fill(f.line_addr);
                        if self.pending_demand[core] == Some(f.line_addr) {
                            self.pending_demand[core] = None;
                        }
                    }
                }
            } else {
                i += 1;
            }
        }

        // 2. One L1 lookup per cycle.
        if let Some((line, kind)) = self.queue.pop_front() {
            self.l1_port_busy += 1;
            let requester = match kind {
                ReqKind::Demand { core } | ReqKind::Prefetch { core } => core as usize,
            };
            // All cores currently demanding this line become waiters
            // (refill logic "responds to all L0 caches in parallel").
            let mut waiters: u32 = 1 << requester;
            for (c, pd) in self.pending_demand.iter().enumerate() {
                if *pd == Some(line) {
                    waiters |= 1 << c;
                }
            }
            if self.l1.lookup(line) {
                self.fills.push(PendingFill {
                    line_addr: line,
                    ready_at: now + self.cfg.l1_hit_latency(),
                    waiters,
                    fill_l1: false,
                });
            } else {
                self.fills.push(PendingFill {
                    line_addr: line,
                    ready_at: u64::MAX,
                    waiters,
                    fill_l1: true,
                });
                return Some((line, self.line_bytes as usize));
            }
        }
        None
    }

    /// True when stepping the icache is a pure timer wait: nothing queued
    /// for the L1 lookup port. In-flight fills do not disturb quiet — each
    /// completes at its `ready_at` stamp, which [`next_fill_at`] exposes as
    /// a wake-up source to the quiescence fast path.
    ///
    /// [`next_fill_at`]: TileICache::next_fill_at
    pub fn quiet(&self) -> bool {
        self.queue.is_empty()
    }

    /// Earliest cycle at which an in-flight fill completes (wake-up source
    /// for the quiescence fast path). Unresolved AXI refills sit at
    /// `ready_at == u64::MAX`, but cannot coexist with a quiescent cluster
    /// — they are resolved in the same cycle they are deferred.
    pub fn next_fill_at(&self) -> Option<u64> {
        self.fills
            .iter()
            .map(|f| f.ready_at)
            .filter(|&r| r != u64::MAX)
            .min()
    }

    /// Set the completion time of the refill deferred by [`step_deferred`].
    pub fn resolve_refill(&mut self, line: u32, ready_at: u64) {
        let fill = self
            .fills
            .iter_mut()
            .find(|f| f.fill_l1 && f.line_addr == line && f.ready_at == u64::MAX)
            .expect("resolve_refill without a deferred refill");
        fill.ready_at = ready_at;
    }

    /// Flush everything (used between benchmark phases for cold-start runs).
    pub fn invalidate_all(&mut self) {
        for l0 in &mut self.l0 {
            l0.invalidate_all();
        }
        self.l1.invalidate_all();
        self.queue.clear();
        self.fills.clear();
        self.pending_demand.iter_mut().for_each(|p| *p = None);
    }
}
