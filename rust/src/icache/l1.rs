//! Shared per-tile L1 instruction cache (paper §4.1): configurable
//! set-associative lookup (parallel or serial tag-then-data), refill
//! coalescing, round-robin replacement.

use super::config::ICacheConfig;

/// Event counters feeding the energy model (paper Fig 6). "Reads" are
/// per-bank accesses: a parallel lookup reads every tag and data way;
/// a serial lookup reads every tag way but only the hitting data way.
#[derive(Debug, Clone, Copy, Default)]
pub struct L1Counters {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub tag_reads: u64,
    pub data_reads: u64,
    pub refills: u64,
}

/// Tag-only model of the shared L1 instruction cache (instruction bits
/// always come from the immutable `Program`, so only presence is tracked).
#[derive(Debug, Clone)]
pub struct L1ICache {
    /// `tags[set * ways + way]` = line address or `u32::MAX`.
    tags: Vec<u32>,
    sets: usize,
    ways: usize,
    line_bytes: u32,
    serial: bool,
    /// Round-robin victim pointer per set.
    victim: Vec<u8>,
    pub counters: L1Counters,
}

impl L1ICache {
    pub fn new(cfg: &ICacheConfig) -> Self {
        let sets = cfg.l1_sets();
        L1ICache {
            tags: vec![u32::MAX; sets * cfg.l1_ways],
            sets,
            ways: cfg.l1_ways,
            line_bytes: cfg.line_bytes() as u32,
            serial: cfg.serial_lookup,
            victim: vec![0; sets],
            counters: L1Counters::default(),
        }
    }

    fn set_of(&self, line_addr: u32) -> usize {
        ((line_addr / self.line_bytes) as usize) % self.sets
    }

    /// Probe without counting (used by refill coalescing).
    pub fn contains(&self, line_addr: u32) -> bool {
        let set = self.set_of(line_addr);
        self.tags[set * self.ways..(set + 1) * self.ways].contains(&line_addr)
    }

    /// Perform a lookup, updating the event counters. Returns hit/miss.
    pub fn lookup(&mut self, line_addr: u32) -> bool {
        self.counters.lookups += 1;
        // Both organizations read all tag ways in parallel.
        self.counters.tag_reads += self.ways as u64;
        let hit = self.contains(line_addr);
        if hit {
            self.counters.hits += 1;
            // Parallel: all data ways are read speculatively.
            // Serial: only the hitting way's (merged) data bank is read.
            self.counters.data_reads += if self.serial { 1 } else { self.ways as u64 };
        } else {
            self.counters.misses += 1;
            if !self.serial {
                // The parallel organization has already burned the data
                // reads by the time the hit calculation resolves.
                self.counters.data_reads += self.ways as u64;
            }
        }
        hit
    }

    /// Install a refilled line (round-robin within the set). Idempotent.
    pub fn fill(&mut self, line_addr: u32) {
        if self.contains(line_addr) {
            return;
        }
        self.counters.refills += 1;
        let set = self.set_of(line_addr);
        let way = self.victim[set] as usize % self.ways;
        self.victim[set] = self.victim[set].wrapping_add(1);
        self.tags[set * self.ways + way] = line_addr;
    }

    pub fn invalidate_all(&mut self) {
        self.tags.fill(u32::MAX);
        self.victim.fill(0);
    }
}
