//! Private per-core L0 instruction cache (paper §4.1): minimal, fully
//! associative, standard-cell based, with a prefetcher that scans the
//! current line for backward branches (loops) to fetch the predicted next
//! line before the core needs it.

use crate::isa::{Instr, Program};

/// Fully associative L0 cache holding `lines` cache-line tags.
/// Replacement is FIFO (a shift register in hardware).
#[derive(Debug, Clone)]
pub struct L0Cache {
    lines: Vec<u32>,
    capacity: usize,
    next_victim: usize,
    /// Line address of the last fetch, to detect line transitions for the
    /// prefetcher.
    last_line: u32,
    pub hits: u64,
    pub misses: u64,
    pub prefetches: u64,
}

impl L0Cache {
    pub fn new(lines: usize) -> Self {
        L0Cache {
            lines: Vec::with_capacity(lines),
            capacity: lines,
            next_victim: 0,
            last_line: u32::MAX,
            hits: 0,
            misses: 0,
            prefetches: 0,
        }
    }

    pub fn contains(&self, line_addr: u32) -> bool {
        self.lines.contains(&line_addr)
    }

    /// Install a line, evicting FIFO if full. Idempotent.
    pub fn fill(&mut self, line_addr: u32) {
        if self.contains(line_addr) {
            return;
        }
        if self.lines.len() < self.capacity {
            self.lines.push(line_addr);
        } else {
            self.lines[self.next_victim] = line_addr;
            self.next_victim = (self.next_victim + 1) % self.capacity;
        }
    }

    pub fn invalidate_all(&mut self) {
        self.lines.clear();
        self.next_victim = 0;
        self.last_line = u32::MAX;
    }

    /// Record a fetch; returns `(hit, entered_new_line)`.
    pub fn access(&mut self, line_addr: u32) -> (bool, bool) {
        let new_line = line_addr != self.last_line;
        self.last_line = line_addr;
        if self.contains(line_addr) {
            self.hits += 1;
            (true, new_line)
        } else {
            self.misses += 1;
            (false, new_line)
        }
    }
}

/// Prefetch prediction: scan the line for a backward branch or a
/// predictable jump (`jal`); if found, predict its target's line,
/// otherwise predict the next sequential line (paper §4.1).
pub fn predicted_next_line(program: &Program, line_addr: u32, line_bytes: u32) -> Option<u32> {
    let first_idx = match program.index_of(line_addr.max(program.base)) {
        Some(i) => i,
        None => return None,
    };
    let line_mask = !(line_bytes - 1);
    let per_line = line_bytes / 4;
    for idx in first_idx..(first_idx + per_line).min(program.len() as u32) {
        match program.get(idx) {
            Some(Instr::Branch { target, .. }) if *target <= idx => {
                // Backward branch: a loop — predict the target line.
                return Some(program.addr_of(*target) & line_mask);
            }
            Some(Instr::Jal { target, .. }) => {
                // Predictable jump.
                return Some(program.addr_of(*target) & line_mask);
            }
            _ => {}
        }
    }
    // Sequential next line, if it still holds program text.
    let next = (line_addr & line_mask) + line_bytes;
    program.index_of(next).map(|_| next)
}
