//! Tests for the instruction cache hierarchy.

use super::tile::{FixedLatencyPort, RefillPort};
use super::*;
use crate::isa::Program;

fn straightline_program(n: usize) -> Program {
    let src = vec!["nop"; n].join("\n");
    Program::assemble_simple(&src).unwrap()
}

fn loop_program() -> Program {
    // 12-instruction loop body plus header — fits in a 32-instr L0.
    Program::assemble_simple(
        "li a0, 100\n\
         loop: addi a0, a0, -1\n\
         nop\nnop\nnop\nnop\nnop\nnop\nnop\nnop\nnop\nnop\n\
         bnez a0, loop\n\
         halt",
    )
    .unwrap()
}

#[test]
fn l0_fifo_replacement() {
    let mut l0 = L0Cache::new(2);
    l0.fill(0x100);
    l0.fill(0x200);
    assert!(l0.contains(0x100) && l0.contains(0x200));
    l0.fill(0x300); // evicts 0x100 (FIFO)
    assert!(!l0.contains(0x100));
    assert!(l0.contains(0x200) && l0.contains(0x300));
    l0.fill(0x300); // idempotent
    assert!(l0.contains(0x200));
}

#[test]
fn l1_set_associative_behaviour() {
    let cfg = ICacheConfig::two_way();
    let mut l1 = L1ICache::new(&cfg);
    let sets = cfg.l1_sets() as u32;
    let line = cfg.line_bytes() as u32;
    let a = 0x8000_0000u32;
    let b = a + sets * line; // same set, different tag
    let c = b + sets * line;
    l1.fill(a);
    l1.fill(b);
    assert!(l1.lookup(a) && l1.lookup(b));
    l1.fill(c); // evicts round-robin (a)
    assert!(!l1.lookup(a));
    assert!(l1.lookup(b) && l1.lookup(c));
}

#[test]
fn serial_lookup_reads_one_data_way() {
    let mut par = L1ICache::new(&ICacheConfig::two_way());
    let mut ser = L1ICache::new(&ICacheConfig::serial_l1());
    par.fill(0x8000_0000);
    ser.fill(0x8000_0000);
    par.lookup(0x8000_0000);
    ser.lookup(0x8000_0000);
    assert_eq!(par.counters.data_reads, 2, "parallel reads all ways");
    assert_eq!(ser.counters.data_reads, 1, "serial reads only the hit way");
    assert_eq!(par.counters.tag_reads, 2);
    assert_eq!(ser.counters.tag_reads, 2);
    // On a miss, serial saves the data reads entirely.
    par.lookup(0x9000_0000);
    ser.lookup(0x9000_0000);
    assert_eq!(par.counters.data_reads, 4);
    assert_eq!(ser.counters.data_reads, 1);
}

#[test]
fn cold_fetch_misses_then_hits() {
    let prog = straightline_program(16);
    let cfg = ICacheConfig::final_optimized();
    let mut ic = TileICache::new(cfg, 4);
    let mut port = FixedLatencyPort(20);
    let addr = prog.addr_of(0);

    assert_eq!(ic.fetch(0, addr, &prog), FetchResult::Stall);
    // Stall persists until the refill lands (1 queue cycle + 20).
    let mut cycle = 0u64;
    let mut stalled = 0u64;
    loop {
        ic.step(cycle, &mut port);
        match ic.fetch(0, addr, &prog) {
            FetchResult::Ready => break,
            FetchResult::Stall => stalled += 1,
        }
        cycle += 1;
        assert!(cycle < 100, "refill never completed");
    }
    assert!(stalled >= 20, "expected ≥20 stall cycles, got {stalled}");
    // Subsequent instructions in the same line hit immediately.
    assert_eq!(ic.fetch(0, addr + 4, &prog), FetchResult::Ready);
}

#[test]
fn refill_coalescing_serves_all_cores() {
    let prog = straightline_program(16);
    let mut ic = TileICache::new(ICacheConfig::final_optimized(), 4);
    let mut port = CountingPort { latency: 15, reads: 0 };
    let addr = prog.addr_of(0);
    for core in 0..4 {
        assert_eq!(ic.fetch(core, addr, &prog), FetchResult::Stall);
    }
    for cycle in 0..40 {
        ic.step(cycle, &mut port);
    }
    for core in 0..4 {
        assert_eq!(ic.fetch(core, addr, &prog), FetchResult::Ready, "core {core}");
    }
    assert_eq!(port.reads, 1, "four demand misses must coalesce into one refill");
}

struct CountingPort {
    latency: u64,
    reads: u64,
}

impl RefillPort for CountingPort {
    fn read(&mut self, _addr: u32, _bytes: usize, now: u64) -> u64 {
        self.reads += 1;
        now + self.latency
    }
}

/// Walk a core through the program, stepping the cache each cycle; returns
/// (cycles, stalls).
fn run_sequence(ic: &mut TileICache, prog: &Program, port: &mut dyn RefillPort) -> (u64, u64) {
    let mut cycle = 0u64;
    let mut stalls = 0u64;
    let mut pc = 0u32;
    // Interpret just enough to follow branches: we only run nop/addi/bnez/li.
    let mut a0: i64 = 0;
    while (pc as usize) < prog.len() {
        ic.step(cycle, port);
        match ic.fetch(0, prog.addr_of(pc), prog) {
            FetchResult::Ready => {
                use crate::isa::{CondOp, Instr};
                match prog.get(pc).unwrap() {
                    Instr::Halt => break,
                    Instr::OpImm { imm, rd, .. } if rd.index() == 10 => {
                        // li a0 / addi a0
                        if *imm == -1 {
                            a0 -= 1;
                        } else {
                            a0 = *imm as i64;
                        }
                        pc += 1;
                    }
                    Instr::Branch { cond: CondOp::Ne, target, .. } => {
                        if a0 != 0 {
                            pc = *target;
                        } else {
                            pc += 1;
                        }
                    }
                    _ => pc += 1,
                }
            }
            FetchResult::Stall => stalls += 1,
        }
        cycle += 1;
        assert!(cycle < 1_000_000);
    }
    (cycle, stalls)
}

#[test]
fn prefetch_hides_loop_misses() {
    let prog = loop_program();
    let mut port = FixedLatencyPort(20);
    let mut with_pf = TileICache::new(ICacheConfig::final_optimized(), 1);
    let (_, stalls_pf) = run_sequence(&mut with_pf, &prog, &mut port);

    let mut cfg_no = ICacheConfig::final_optimized();
    cfg_no.prefetch = false;
    let mut without = TileICache::new(cfg_no, 1);
    let mut port2 = FixedLatencyPort(20);
    let (_, stalls_no) = run_sequence(&mut without, &prog, &mut port2);

    assert!(
        stalls_pf <= stalls_no,
        "prefetch must not increase stalls: {stalls_pf} vs {stalls_no}"
    );
    // After warm-up, the loop fits in L0: steady state has zero stalls.
    assert!(stalls_pf < 60, "loop execution should be nearly stall-free, got {stalls_pf}");
}

#[test]
fn big_kernel_thrashes_l0_but_hits_l1() {
    // 64 instructions > 32-instr L0, but < 512-instr L1.
    let prog = straightline_program(64);
    let mut ic = TileICache::new(ICacheConfig::final_optimized(), 1);
    let mut port = FixedLatencyPort(20);
    let (_, first_pass_stalls) = run_sequence(&mut ic, &prog, &mut port);
    assert!(first_pass_stalls > 0);
    let l1_misses_after_first = ic.l1.counters.misses;
    // Second pass: L1 holds everything; only L0 misses remain.
    let (_, _) = run_sequence(&mut ic, &prog, &mut port);
    assert_eq!(
        ic.l1.counters.misses, l1_misses_after_first,
        "second pass must not miss in L1"
    );
}

#[test]
fn invalidate_clears_everything() {
    let prog = straightline_program(8);
    let mut ic = TileICache::new(ICacheConfig::final_optimized(), 2);
    let mut port = FixedLatencyPort(5);
    let _ = run_sequence(&mut ic, &prog, &mut port);
    ic.invalidate_all();
    assert_eq!(ic.fetch(0, prog.addr_of(0), &prog), FetchResult::Stall);
}

#[test]
fn predicted_next_line_backward_branch() {
    use super::l0::predicted_next_line;
    let prog = loop_program();
    // Find the line containing the bnez (instruction index 12).
    let line_bytes = 32u32;
    let bnez_line = prog.addr_of(12) & !(line_bytes - 1);
    let predicted = predicted_next_line(&prog, bnez_line, line_bytes).unwrap();
    // The backward branch targets instruction 1 (loop:) whose line is line 0.
    assert_eq!(predicted, prog.addr_of(1) & !(line_bytes - 1));
}
