//! Bench E12 — regenerate §8.2.2: application speedups as a fraction of
//! the ideal (histeq / raytrace / BFS).

use mempool::brow;
use mempool::config::ClusterConfig;
use mempool::studies::apps_study;
use mempool::util::bench::section;
use mempool::util::cli::Args;

fn main() {
    let cores: usize = Args::from_env().parse_or("cores", 64);
    let cfg = ClusterConfig::with_cores(cores);
    section(&format!("§8.2.2 — applications on {cores} cores"));
    brow!("app", "cycles", "% of ideal", "sync share");
    for r in apps_study(&cfg) {
        brow!(
            r.app,
            r.cycles,
            format!("{:.0}%", 100.0 * r.fraction_of_ideal),
            format!("{:.0}%", 100.0 * r.sync_share)
        );
    }
    println!("\npaper: histeq ≈40% (Amdahl), raytrace ≈91%, BFS ≈51% of ideal");
}
