//! Bench E10 — regenerate Fig 14: the per-kernel cycle breakdown
//! (compute / control / synchronization / I$ / LSU / RAW).

use mempool::brow;
use mempool::config::ClusterConfig;
use mempool::studies::fig14_breakdown;
use mempool::util::bench::section;

fn main() {
    let cfg = ClusterConfig::mempool();
    section("Fig 14 — cycle breakdown on 256 cores (%)");
    brow!("kernel", "compute", "control", "sync", "I$", "LSU", "RAW");
    for (name, s) in fig14_breakdown(&cfg) {
        let b = s.breakdown();
        brow!(
            name,
            format!("{:.0}", 100.0 * b.compute),
            format!("{:.0}", 100.0 * b.control),
            format!("{:.0}", 100.0 * b.synchronization),
            format!("{:.1}", 100.0 * b.ifetch),
            format!("{:.1}", 100.0 * b.lsu),
            format!("{:.1}", 100.0 * b.raw)
        );
    }
    println!("\npaper: compute kernels ≤66% compute; only matmul shows LSU stalls;");
    println!("RAW/I$ stalls negligible; memory system stalls ≈4% on average");
}
