//! Bench E6 — regenerate Fig 10: system-bus utilization vs transfer size
//! for 1/2/4/8/16 DMA backends per group.

use mempool::brow;
use mempool::studies::fig10_dma;
use mempool::util::bench::section;

fn main() {
    section("Fig 10 — AXI utilization vs transfer size per backend count");
    brow!("backends/group", "KiB", "utilization", "cycles");
    for r in fig10_dma() {
        brow!(
            r.backends_per_group,
            r.bytes / 1024,
            format!("{:.2}", r.utilization),
            r.completion_cycles
        );
    }
    println!("\npaper: 1–8 backends converge to full utilization on large transfers;");
    println!("16 backends collapse (single-tile ownership kills AXI bursts)");
}
