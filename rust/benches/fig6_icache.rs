//! Bench E3/E4 — regenerate Fig 6 (icache power) and Fig 7 (tile energy)
//! across the six cache architectures of §4.1.

use mempool::brow;
use mempool::studies::fig6_icache;
use mempool::util::bench::section;

fn main() {
    section("Fig 6/7 — instruction cache optimization steps");
    brow!("config", "kGE", "small $ mW", "big $ mW", "small cyc", "big cyc", "tile mW");
    let rows = fig6_icache();
    for r in &rows {
        brow!(
            r.config,
            r.area_kge,
            format!("{:.2}", r.small_icache_mw),
            format!("{:.2}", r.big_icache_mw),
            r.small_cycles,
            r.big_cycles,
            format!("{:.2}", r.big_tile_mw)
        );
    }
    let base = &rows[0];
    let last = rows.last().unwrap();
    println!(
        "\nicache power saving: small {:.0}% (paper −75%), big {:.0}% (paper −48%); area −{:.0}% (paper −17%)",
        100.0 * (1.0 - last.small_icache_mw / base.small_icache_mw),
        100.0 * (1.0 - last.big_icache_mw / base.big_icache_mw),
        100.0 * (1.0 - last.area_kge / base.area_kge)
    );
}
