//! Perf bench — the busy-path host-speedup scoreboard for the
//! pre-decoded issue path, the parked-core fast path, and the
//! allocation-free exchange phase (see `docs/ARCHITECTURE.md`, Host
//! performance model).
//!
//! Every scenario runs through `studies::grid::run_point` — the exact
//! path the report campaign measures — so the `sim_cycles_per_sec`
//! printed here is the same `host.sim_cycles_per_sec` field CI's
//! `mempool report --host-tolerance` gates on. Scenarios cover the CI
//! shape (minpool, 16 cores) and the paper shape (mempool, 256 cores)
//! on a compute-bound and a memory/burst-bound kernel, on both stepping
//! engines. Compare a before/after pair of runs of this bench to quote
//! a host-speedup ratio.

use mempool::runtime::ExecOptions;
use mempool::sim::SimBackend;
use mempool::util::bench::section;

fn main() {
    section("Host throughput — simulated cycles per host second");
    let exec = ExecOptions::default();
    let scenarios: &[(&str, &str, usize)] = &[
        ("minpool", "matmul", 16),
        ("mempool", "axpy", 256),
        ("mempool", "matmul", 256),
        ("mempool", "axpy_burst", 256),
    ];
    println!(
        "{:>8} {:>12} {:>5} {:>9} | {:>12} {:>9} {:>14}",
        "preset", "kernel", "cores", "backend", "cycles", "wall s", "M sim-cyc/s"
    );
    for &(preset, kernel, cores) in scenarios {
        for backend in [SimBackend::Serial, SimBackend::Parallel] {
            let p = mempool::studies::grid::run_point(preset, kernel, 1, cores, backend, &exec)
                .unwrap_or_else(|e| panic!("{preset} {kernel} @ {cores}: {e}"));
            println!(
                "{:>8} {:>12} {:>5} {:>9} | {:>12} {:>9.3} {:>14.2}",
                preset,
                kernel,
                cores,
                backend.name(),
                p.cycles,
                p.wall_ms / 1e3,
                p.sim_cycles_per_sec() / 1e6
            );
        }
    }
}
