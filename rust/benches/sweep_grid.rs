//! Perf bench — the scenario sweep runner's wall-clock on the CI grid:
//! threaded grid + parallel tile stepping vs. one thread forcing the
//! serial backend. This is the speedup the sweep runner exists for
//! (large configuration studies like Fig 13/14 are grids of independent
//! kernel runs).

use std::time::Instant;

use mempool::brow;
use mempool::sim::SimBackend;
use mempool::studies::sweep::{run_sweep, SweepSpec};
use mempool::util::bench::section;
use mempool::util::par::default_jobs;

fn time_grid(backend: SimBackend, jobs: usize) -> f64 {
    let spec = SweepSpec { backend, jobs, ..SweepSpec::ci_default() };
    let t0 = Instant::now();
    let points = run_sweep(&spec).expect("sweep");
    assert_eq!(points.len(), spec.grid().len());
    t0.elapsed().as_secs_f64()
}

fn main() {
    section("Sweep grid wall-clock — serial 1-thread vs parallel N-thread");
    let jobs = default_jobs();
    // Warm up allocators and the thread pool once.
    let _ = time_grid(SimBackend::Serial, 1);
    let serial = time_grid(SimBackend::Serial, 1);
    let parallel = time_grid(SimBackend::Parallel, jobs);
    brow!("mode", "jobs", "wall s");
    brow!("serial backend", 1, format!("{serial:.3}"));
    brow!("parallel backend", jobs, format!("{parallel:.3}"));
    println!(
        "\nspeedup: {:.2}x on the {}-point CI grid ({} worker threads)",
        serial / parallel,
        SweepSpec::ci_default().grid().len(),
        jobs
    );
}
