//! Bench E1 — regenerate Fig 4: throughput + average latency vs injected
//! load for Top1 / Top4 / TopH (Poisson traffic, uniform banks).

use mempool::brow;
use mempool::studies::fig4;
use mempool::util::bench::{bench_config, section};

fn main() {
    section("Fig 4 — L1 interconnect topologies under Poisson traffic");
    brow!("topology", "load", "throughput", "avg latency", "saturated");
    for pt in fig4(4000) {
        brow!(
            pt.topology.name(),
            format!("{:.2}", pt.lambda),
            format!("{:.3}", pt.throughput),
            format!("{:.1}", pt.avg_latency),
            pt.saturated
        );
    }
    println!("\npaper: Top1 congests ≈0.10 req/core/cycle; Top4 ≈0.37; TopH ≈0.40;");
    println!("TopH average latency < 6 cycles at 0.35 req/core/cycle");
    bench_config("fig4: one TopH point (λ=0.2, 4k cycles)", 1, 3, &mut || {
        let cfg = mempool::trafficgen::NetSimConfig::fig4(mempool::config::Topology::TopH, 0.2);
        std::hint::black_box(mempool::trafficgen::run_netsim(&cfg));
    });
}
