//! Bench E14 — regenerate Fig 17: the hierarchical power breakdown of a
//! matmul run (cores vs SPM interconnect vs banks).

use mempool::brow;
use mempool::config::ClusterConfig;
use mempool::studies::fig17_power;
use mempool::util::bench::section;
use mempool::util::cli::Args;

fn main() {
    let cores: usize = Args::from_env().parse_or("cores", 256);
    let cfg = ClusterConfig::with_cores(cores);
    let (r, c, n, b) = fig17_power(&cfg);
    section(&format!("Fig 17 — power breakdown, matmul on {cores} cores"));
    brow!("total power", format!("{:.2} W", r.stats.power_w(cfg.clock_hz)));
    brow!("cores + icache", format!("{:.0}%", 100.0 * c));
    brow!("SPM interconnect", format!("{:.0}%", 100.0 * n));
    brow!("SPM banks", format!("{:.0}%", 100.0 * b));
    brow!("other", format!("{:.0}%", 100.0 * (1.0 - c - n - b)));
    println!("\npaper: cores 56%, interconnect 30%, banks 7% of ≈1.67 W");
}
