//! Bench E5 — regenerate the §5.5 study: RO cache and AXI radix on a
//! cold-start kernel's instruction path.

use mempool::brow;
use mempool::studies::rocache_study;
use mempool::util::bench::section;

fn main() {
    section("§5.5 — RO cache + AXI radix, cold-start matmul");
    brow!("configuration", "cycles", "speedup");
    for r in rocache_study() {
        brow!(r.label, r.cycles, format!("{:.2}x", r.speedup_vs_cacheless));
    }
    println!("\npaper: radix-8 1.59x, radix-16 1.54x over cacheless; radix-16 chosen");
}
