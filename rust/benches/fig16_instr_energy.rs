//! Bench E13 — regenerate Fig 16: energy per instruction, including the
//! local-vs-remote load ratio and the MAC-fusion saving.

use mempool::brow;
use mempool::studies::fig16_instr_energy;
use mempool::util::bench::section;

fn main() {
    section("Fig 16 — energy per instruction (pJ/core/cycle)");
    brow!("instruction", "pJ");
    let rows = fig16_instr_energy();
    for r in &rows {
        brow!(r.instr, format!("{:.2}", r.model_pj));
    }
    let f = |n: &str| rows.iter().find(|r| r.instr == n).unwrap().model_pj;
    println!("\nmac − mul = {:.2} pJ (paper: +0.2 pJ)", f("mac") - f("mul"));
    println!(
        "fusing saves {:.0}% vs mul+add (paper: 36%)",
        100.0 * (1.0 - f("mac") / (f("mul") + f("add")))
    );
    println!("remote/local load = {:.2}x (paper: 1.8x)", f("lw (remote)") / f("lw (local)"));
    println!("remote load / mac = {:.2}x (paper: 1.29x)", f("lw (remote)") / f("mac"));
}
