//! Perf bench — host-side simulator throughput (core-cycles simulated per
//! wall-clock second), the §Perf headline metric for the simulator, for
//! both stepping backends. The parallel backend's advantage grows with
//! the tile count (per-cycle fork/join overhead amortizes over 64 tiles
//! at 256 cores).
//!
//! Scenarios run on the named topology presets through the same
//! `studies::grid::run_point` path the report campaign uses, so the
//! numbers printed here are directly comparable with the
//! `host.sim_cycles_per_sec` column of `mempool report` and with the
//! `host_throughput` bench's busy-path scenarios.

use mempool::config::ClusterConfig;
use mempool::kernels::Matmul;
use mempool::runtime::{run_workload, ExecOptions, RunConfig};
use mempool::sim::SimBackend;
use mempool::studies::grid::run_point;
use mempool::util::bench::{bench_config, section};

fn main() {
    section("Simulator throughput — serial vs parallel tile stepping");
    let exec = ExecOptions::default();
    for backend in [SimBackend::Serial, SimBackend::Parallel] {
        for (preset, cores) in [("minpool", 16usize), ("mempool", 64), ("mempool", 256)] {
            let p = run_point(preset, "matmul", 1, cores, backend, &exec)
                .unwrap_or_else(|e| panic!("{preset} matmul @ {cores}: {e}"));
            let core_cycles = p.cycles * cores as u64;
            println!(
                "{:>8} {preset:>8} {cores:>4} cores: {} cycles in {:.3}s = {:.2} M sim-cycles/s \
                 ({:.1} M core-cycles/s)",
                backend.name(),
                p.cycles,
                p.wall_ms / 1e3,
                p.sim_cycles_per_sec() / 1e6,
                core_cycles as f64 / (p.wall_ms / 1e3) / 1e6
            );
        }
    }
    bench_config("minpool matmul end-to-end", 1, 5, &mut || {
        let cfg = ClusterConfig::minpool();
        let k = Matmul::weak_scaled(16);
        let run = RunConfig::cluster(&cfg).with_backend(SimBackend::Serial);
        std::hint::black_box(run_workload(&k, &run));
    });
    bench_config("minpool matmul end-to-end (parallel)", 1, 5, &mut || {
        let cfg = ClusterConfig::minpool();
        let k = Matmul::weak_scaled(16);
        let run = RunConfig::cluster(&cfg).with_backend(SimBackend::Parallel);
        std::hint::black_box(run_workload(&k, &run));
    });
}
