//! Perf bench — host-side simulator throughput (core-cycles simulated per
//! wall-clock second), the §Perf headline metric for the simulator, for
//! both stepping backends. The parallel backend's advantage grows with
//! the tile count (per-cycle fork/join overhead amortizes over 64 tiles
//! at 256 cores).

use mempool::config::ClusterConfig;
use mempool::kernels::Matmul;
use mempool::runtime::{run_workload, RunConfig};
use mempool::sim::SimBackend;
use mempool::util::bench::{bench_config, section};
use std::time::Instant;

fn main() {
    section("Simulator throughput — serial vs parallel tile stepping");
    for backend in [SimBackend::Serial, SimBackend::Parallel] {
        for cores in [16usize, 64, 256] {
            let cfg = ClusterConfig::with_cores(cores);
            let k = Matmul::weak_scaled(cores);
            let t0 = Instant::now();
            let r = run_workload(&k, &RunConfig::cluster(&cfg).with_backend(backend));
            let dt = t0.elapsed().as_secs_f64();
            let core_cycles = r.cycles * cores as u64;
            println!(
                "{:>8} {cores:>4} cores: {} cycles in {:.3}s = {:.1} M core-cycles/s",
                backend.name(),
                r.cycles,
                dt,
                core_cycles as f64 / dt / 1e6
            );
        }
    }
    bench_config("minpool matmul end-to-end", 1, 5, &mut || {
        let cfg = ClusterConfig::minpool();
        let k = Matmul::weak_scaled(16);
        let run = RunConfig::cluster(&cfg).with_backend(SimBackend::Serial);
        std::hint::black_box(run_workload(&k, &run));
    });
    bench_config("minpool matmul end-to-end (parallel)", 1, 5, &mut || {
        let cfg = ClusterConfig::minpool();
        let k = Matmul::weak_scaled(16);
        let run = RunConfig::cluster(&cfg).with_backend(SimBackend::Parallel);
        std::hint::black_box(run_workload(&k, &run));
    });
}
