//! Perf bench — host-side simulator throughput (core-cycles simulated per
//! wall-clock second), the §Perf headline metric for the simulator.

use mempool::config::ClusterConfig;
use mempool::kernels::{run_and_verify, Matmul};
use mempool::util::bench::{bench_config, section};
use std::time::Instant;

fn main() {
    section("Simulator throughput");
    for cores in [16usize, 64, 256] {
        let cfg = ClusterConfig::with_cores(cores);
        let k = Matmul::weak_scaled(cores);
        let t0 = Instant::now();
        let r = run_and_verify(&k, &cfg);
        let dt = t0.elapsed().as_secs_f64();
        let core_cycles = r.cycles * cores as u64;
        println!(
            "{cores:>4} cores: {} cycles in {:.3}s = {:.1} M core-cycles/s",
            r.cycles,
            dt,
            core_cycles as f64 / dt / 1e6
        );
    }
    bench_config("minpool matmul end-to-end", 1, 5, &mut || {
        let cfg = ClusterConfig::minpool();
        let k = Matmul::weak_scaled(16);
        std::hint::black_box(run_and_verify(&k, &cfg));
    });
}
