//! Bench E2 — regenerate Fig 5: TopH with the hybrid addressing scheme
//! for different probabilities of hitting the local sequential region.

use mempool::brow;
use mempool::studies::fig5;
use mempool::util::bench::section;

fn main() {
    section("Fig 5 — hybrid addressing: throughput/latency vs p_local");
    brow!("p_local", "load", "throughput", "avg latency");
    for (p, pts) in fig5(4000) {
        for pt in pts {
            brow!(
                format!("{p:.2}"),
                format!("{:.2}", pt.lambda),
                format!("{:.3}", pt.throughput),
                format!("{:.1}", pt.avg_latency)
            );
        }
    }
    println!("\npaper: larger p_local raises sustainable throughput and lowers latency;");
    println!("25% stack-local accesses gain up to 27% performance");
}
