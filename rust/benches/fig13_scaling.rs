//! Bench E9 — regenerate Fig 13: weak scaling 4→256 cores with and
//! without the final synchronization barrier.

use mempool::brow;
use mempool::studies::fig13_scaling;
use mempool::util::bench::section;

fn main() {
    section("Fig 13 — weak scaling vs ideal single core");
    brow!("kernel", "cores", "speedup", "w/o barrier", "% of ideal");
    for r in fig13_scaling(&[4, 16, 64, 256]) {
        brow!(
            r.kernel,
            r.cores,
            format!("{:.1}", r.speedup),
            format!("{:.1}", r.speedup_no_barrier),
            format!("{:.0}%", 100.0 * r.speedup / r.ideal)
        );
    }
    println!("\npaper: compute-intensive kernels near-ideal (−10% from the barrier);");
    println!("memory-bound kernels ≈75% of ideal");
}
