//! Bench E8 — regenerate Table 1: the five DSP kernels on the full
//! 256-core cluster with IPC, power, OP/cycle, and GOPS/W.

use mempool::brow;
use mempool::config::ClusterConfig;
use mempool::studies::table1;
use mempool::util::bench::{bench_config, section};

fn main() {
    let cfg = ClusterConfig::mempool();
    section("Table 1 — kernel metrics on 256 cores @600 MHz");
    brow!("kernel", "cycles", "IPC", "OP/cycle", "GOPS", "W", "GOPS/W");
    for r in table1(&cfg) {
        brow!(
            r.kernel,
            r.cycles,
            format!("{:.2}", r.ipc),
            format!("{:.0}", r.ops_per_cycle),
            format!("{:.0}", r.gops),
            format!("{:.2}", r.power_w),
            format!("{:.0}", r.gops_per_w)
        );
    }
    println!("\npaper: matmul 285 OP/cycle @0.88 IPC; 2dconv 336 @0.87; dct 168 @0.93;");
    println!("axpy 90 @0.76; dotp 92 @0.74; cluster ≈1.5 W");
    bench_config("table1: 16-core matmul end-to-end", 1, 3, &mut || {
        let cfg = ClusterConfig::minpool();
        let k = mempool::kernels::Matmul::weak_scaled(16);
        let run = mempool::runtime::RunConfig::cluster(&cfg);
        std::hint::black_box(mempool::runtime::run_workload(&k, &run));
    });
}
