//! Bench E11 — regenerate Fig 15: double-buffered kernels with DMA
//! streaming overlapped with compute.

use mempool::brow;
use mempool::config::ClusterConfig;
use mempool::studies::fig15_doublebuf;
use mempool::util::bench::section;
use mempool::util::cli::Args;

fn main() {
    let cores: usize = Args::from_env().parse_or("cores", 64);
    let cfg = ClusterConfig::with_cores(cores);
    section(&format!("Fig 15 — double-buffered execution ({cores} cores)"));
    brow!("kernel", "cycles", "IPC", "OP/cyc", "compute frac", "DMA txns", "DMA KiB");
    for r in fig15_doublebuf(&cfg) {
        brow!(
            r.kernel,
            r.cycles,
            format!("{:.2}", r.ipc),
            format!("{:.1}", r.ops_per_cycle),
            format!("{:.2}", r.compute_fraction),
            r.dma_transfers,
            r.dma_bytes / 1024
        );
    }
    println!("\npaper: compute-bound kernels reach IPC ≈0.94–0.99 in steady rounds;");
    println!("axpy/dotp compute phases only fill 35%/51% of a round (L2-bandwidth bound)");
}
