//! Fig 13: weak-scaling study — speedup over an idealized (IPC = 1,
//! conflict-free) single core, with and without the final barrier.
//!
//! ```sh
//! cargo run --release --example weak_scaling -- --cores 4,16,64
//! ```

use mempool::brow;
use mempool::studies::fig13_scaling;
use mempool::util::bench::section;
use mempool::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cores: Vec<usize> = args
        .list("cores")
        .map(|v| v.iter().map(|s| s.parse().expect("core count")).collect())
        .unwrap_or_else(|| vec![4, 16, 64]);
    section("Fig 13 — weak scaling (speedup vs ideal single core)");
    brow!("kernel", "cores", "speedup", "w/o barrier", "% of ideal");
    for r in fig13_scaling(&cores) {
        brow!(
            r.kernel,
            r.cores,
            format!("{:.1}", r.speedup),
            format!("{:.1}", r.speedup_no_barrier),
            format!("{:.0}%", 100.0 * r.speedup / r.ideal)
        );
    }
}
