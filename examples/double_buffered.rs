//! Fig 15: double-buffered kernels streaming data from L2 through the
//! distributed DMA while computing — compute-bound (matmul) and
//! memory-bound (axpy) behaviour.
//!
//! ```sh
//! cargo run --release --example double_buffered -- --cores 16
//! ```

use mempool::brow;
use mempool::config::ClusterConfig;
use mempool::studies::fig15_doublebuf;
use mempool::util::bench::section;
use mempool::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cores: usize = args.parse_or("cores", 16);
    let cfg = ClusterConfig::with_cores(cores);
    section(&format!("Fig 15 — double-buffered execution on {cores} cores"));
    brow!("kernel", "cycles", "IPC", "OP/cyc", "compute frac", "DMA txns", "DMA KiB");
    for r in fig15_doublebuf(&cfg) {
        brow!(
            r.kernel,
            r.cycles,
            format!("{:.2}", r.ipc),
            format!("{:.1}", r.ops_per_cycle),
            format!("{:.2}", r.compute_fraction),
            r.dma_transfers,
            r.dma_bytes / 1024
        );
    }
    println!("\n(compute-bound db_matmul keeps a higher compute fraction; memory-bound");
    println!(" db_axpy spends most of each round waiting on L2 bandwidth — Fig 15)");
}
