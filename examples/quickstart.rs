//! Quickstart: build a 16-core MemPool cluster, run a hand-written
//! assembly program on every core, and read the results back from the
//! shared L1 SPM.
//!
//! ```sh
//! cargo run --example quickstart
//! ```

use mempool::config::ClusterConfig;
use mempool::sim::{base_symbols, run_kernel, RunConfig};

fn main() {
    // A small cluster: 1 group x 4 tiles x 4 cores, 64 KiB of shared L1.
    let cfg = ClusterConfig::minpool();
    let mut symbols = base_symbols(&cfg);

    // Every core multiplies its hart ID by 3 with the Xpulpimg MAC and
    // stores it into a shared result buffer (interleaved region).
    let map = mempool::mem::AddressMap::from_config(&cfg);
    let results = map.seq_total_bytes() + 256;
    symbols.insert("results".into(), results);
    let program = "\
        csrr a0, mhartid\n\
        li a1, 3\n\
        li a2, 0\n\
        p.mac a2, a0, a1\n\
        la a3, results\n\
        slli a4, a0, 2\n\
        add a3, a3, a4\n\
        sw a2, 0(a3)\n\
        halt";

    let run = RunConfig::new(cfg.clone());
    let result = run_kernel(&run, program, &symbols, |_| {});
    assert!(result.completed);

    let mut cluster = result.cluster;
    let values = cluster.spm().read_words(results, cfg.num_cores());
    println!("cycles: {}", result.cycles);
    println!("per-core results (hart*3): {values:?}");
    println!(
        "cluster: {} cores, {} tiles, {} KiB L1 SPM, IPC {:.2}",
        cfg.num_cores(),
        cfg.num_tiles(),
        cfg.spm_bytes() / 1024,
        result.stats.ipc()
    );
    assert_eq!(values[5], 15);
    println!("quickstart OK");
}
