//! The Table 1 DSP suite: run all five paper kernels on a chosen cluster
//! size, verify each against its host reference, and print the paper's
//! metrics (IPC, OP/cycle, GOPS, W, GOPS/W).
//!
//! ```sh
//! cargo run --release --example dsp_suite -- --cores 64
//! ```

use mempool::brow;
use mempool::config::ClusterConfig;
use mempool::runtime::{run_workload, table1_workloads, RunConfig, Workload};
use mempool::util::bench::section;
use mempool::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cores: usize = args.parse_or("cores", 64);
    let cfg = ClusterConfig::with_cores(cores);
    section(&format!("Table 1 — DSP suite on {cores} cores @600 MHz"));
    brow!("kernel", "cycles", "IPC", "OP/cycle", "GOPS", "W", "GOPS/W");
    for k in table1_workloads(&cfg) {
        let mut r = run_workload(k.as_ref(), &RunConfig::cluster(&cfg));
        k.verify(&mut r.machine).expect("kernel result mismatch");
        let s = &r.stats;
        brow!(
            k.name(),
            r.cycles,
            format!("{:.2}", s.ipc()),
            format!("{:.1}", s.ops_per_cycle()),
            format!("{:.1}", s.gops(cfg.clock_hz)),
            format!("{:.2}", s.power_w(cfg.clock_hz)),
            format!("{:.0}", s.gops_per_w(cfg.clock_hz))
        );
    }
    println!("\nall kernels verified against their host references");
}
