//! Fig 4 + Fig 5: the L1-interconnect network study with Poisson traffic
//! generators replacing the cores.
//!
//! ```sh
//! cargo run --release --example netsim
//! cargo run --release --example netsim -- --hybrid
//! ```

use mempool::brow;
use mempool::studies::{fig4, fig5};
use mempool::util::bench::section;
use mempool::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cycles: u64 = args.parse_or("cycles", 4000);
    if args.has("hybrid") {
        section("Fig 5 — hybrid addressing (TopH)");
        brow!("p_local", "load", "throughput", "latency");
        for (p, pts) in fig5(cycles) {
            for pt in pts {
                brow!(
                    format!("{p:.2}"),
                    format!("{:.2}", pt.lambda),
                    format!("{:.3}", pt.throughput),
                    format!("{:.1}", pt.avg_latency)
                );
            }
        }
    } else {
        section("Fig 4 — Top1 / Top4 / TopH");
        brow!("topology", "load", "throughput", "latency", "saturated");
        for pt in fig4(cycles) {
            brow!(
                pt.topology.name(),
                format!("{:.2}", pt.lambda),
                format!("{:.3}", pt.throughput),
                format!("{:.1}", pt.avg_latency),
                pt.saturated
            );
        }
    }
}
