//! §8.2.2: the three full applications — histogram equalization (serial
//! sections), ray tracing (imbalanced, dynamically scheduled), and BFS
//! (atomic shared data structures) — with their fraction-of-ideal
//! speedups.
//!
//! ```sh
//! cargo run --release --example apps -- --cores 16
//! ```

use mempool::brow;
use mempool::config::ClusterConfig;
use mempool::studies::apps_study;
use mempool::util::bench::section;
use mempool::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cores: usize = args.parse_or("cores", 16);
    let cfg = ClusterConfig::with_cores(cores);
    section(&format!("§8.2.2 — applications on {cores} cores"));
    brow!("app", "cycles", "% of ideal", "sync share");
    for r in apps_study(&cfg) {
        brow!(
            r.app,
            r.cycles,
            format!("{:.0}%", 100.0 * r.fraction_of_ideal),
            format!("{:.0}%", 100.0 * r.sync_share)
        );
    }
    println!("\n(all three verified against host references; paper: histeq ≈40%,");
    println!(" raytrace ≈91%, bfs ≈51% of ideal)");
}
