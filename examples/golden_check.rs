//! End-to-end three-layer check: run the matmul kernel on the
//! cycle-accurate 16-core cluster AND through the AOT-compiled golden
//! model (Pallas -> JAX -> HLO text -> PJRT), then compare bit-for-bit.
//!
//! ```sh
//! make artifacts && cargo run --release --example golden_check
//! ```

use mempool::config::ClusterConfig;
use mempool::kernels::Matmul;
use mempool::runtime::{artifacts_available, run_workload, RunConfig, Runtime, Workload};

fn main() {
    if !artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let kernel = Matmul::weak_scaled(16);
    let cfg = ClusterConfig::minpool();
    println!(
        "simulating {}x{}x{} matmul on {} cores...",
        kernel.m, kernel.n, kernel.k, cfg.num_cores()
    );
    let mut result = run_workload(&kernel, &RunConfig::cluster(&cfg));
    println!("simulation: {} cycles, IPC {:.2}", result.cycles, result.stats.ipc());

    let mut rt = Runtime::new().expect("PJRT CPU client");
    println!("PJRT platform: {}", rt.platform());
    let (a, b) = {
        let mut rng = mempool::util::Rng::seeded(kernel.seed);
        let a: Vec<i32> = (0..kernel.m * kernel.k).map(|_| rng.below(256) as i32).collect();
        let b: Vec<i32> = (0..kernel.k * kernel.n).map(|_| rng.below(256) as i32).collect();
        (a, b)
    };
    let golden = rt
        .run_i32("matmul", &[(&a, &[kernel.m, kernel.k]), (&b, &[kernel.k, kernel.n])])
        .expect("golden model");

    let cluster = result.machine.cluster();
    let rt_layout = mempool::kernels::rt::RtLayout::new(&cluster.cfg);
    let c_addr = rt_layout.data_base
        + (kernel.m * kernel.k * 4) as u32
        + (kernel.k * kernel.n * 4) as u32;
    let simulated = cluster.spm().read_words(c_addr, kernel.m * kernel.n);
    let mismatches = simulated
        .iter()
        .zip(&golden)
        .filter(|(s, g)| **s as i32 != **g)
        .count();
    println!(
        "compared {} elements: {} mismatches — {}",
        golden.len(),
        mismatches,
        if mismatches == 0 { "BIT-EXACT" } else { "FAILED" }
    );
    assert_eq!(mismatches, 0);
    let _ = kernel.name();
    println!("golden_check OK: simulator == Pallas/JAX/PJRT golden model");
}
