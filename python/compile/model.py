"""Layer-2 JAX compute graphs: the golden models of MemPool's evaluation
kernels, composed from the Layer-1 Pallas kernels where one exists and
from the pure-jnp references elsewhere.

These are what `aot.py` lowers to `artifacts/*.hlo.txt`; the rust
coordinator loads the artifacts through PJRT and uses them to verify the
cycle-accurate simulator's SPM contents bit-for-bit (int32 => exact).
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.matmul_pallas import matmul as pallas_matmul
from .kernels.stream_pallas import axpy as pallas_axpy
from .kernels.stream_pallas import dotp as pallas_dotp


def matmul_model(a, b):
    """Golden matmul: the Pallas kernel inside a jitted graph."""
    return (pallas_matmul(a, b),)


def axpy_model(alpha, x, y):
    return (pallas_axpy(alpha, x, y),)


def dotp_model(x, y):
    return (pallas_dotp(x, y).reshape((1,)),)


def conv2d_model(img, coeff_flat):
    """3x3 convolution; `coeff_flat` is the 9-element stencil."""
    c = [[coeff_flat[3 * r + q] for q in range(3)] for r in range(3)]
    return (ref.conv2d_3x3(img, c),)


def dct_model(blocks):
    """Batched 8x8 integer DCT: blocks has shape (n, 8, 8)."""
    return (jax.vmap(ref.dct8x8)(blocks),)


# Registry used by aot.py: name -> (function, example argument shapes).
def registry(matmul_shape=(64, 32, 32), vec_len=4096, conv_rows=256, dct_blocks=64):
    m, n, k = matmul_shape
    i32 = jnp.int32
    return {
        "matmul": (
            matmul_model,
            [
                jax.ShapeDtypeStruct((m, k), i32),
                jax.ShapeDtypeStruct((k, n), i32),
            ],
        ),
        "axpy": (
            axpy_model,
            [
                jax.ShapeDtypeStruct((), i32),
                jax.ShapeDtypeStruct((vec_len,), i32),
                jax.ShapeDtypeStruct((vec_len,), i32),
            ],
        ),
        "dotp": (
            dotp_model,
            [
                jax.ShapeDtypeStruct((vec_len,), i32),
                jax.ShapeDtypeStruct((vec_len,), i32),
            ],
        ),
        "conv2d": (
            conv2d_model,
            [
                jax.ShapeDtypeStruct((conv_rows, 16), i32),
                jax.ShapeDtypeStruct((9,), i32),
            ],
        ),
        "dct": (
            dct_model,
            [jax.ShapeDtypeStruct((dct_blocks, 8, 8), i32)],
        ),
    }
