"""AOT lowering: jax (Layer 2, calling the Layer-1 Pallas kernels) to HLO
*text* artifacts the rust runtime loads via the `xla` crate.

HLO text, NOT `lowered.compile()`/serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
published `xla` 0.1.6 crate's backend) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

The golden-model shapes default to the shapes the rust examples/tests
exercise (Matmul::weak_scaled(16) on the 16-core minpool, etc.). Run
`make artifacts` to (re)build; it is a no-op when inputs are unchanged.
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import registry


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="also write the matmul HLO here (Makefile stamp)")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, (fn, shapes) in registry().items():
        lowered = jax.jit(fn).lower(*shapes)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")
    if args.out:
        stamp = pathlib.Path(args.out)
        stamp.parent.mkdir(parents=True, exist_ok=True)
        stamp.write_text((out_dir / "matmul.hlo.txt").read_text())
        print(f"stamp {stamp}")


if __name__ == "__main__":
    main()
