"""Pure-jnp oracles for the Pallas kernels and the Layer-2 golden models.

Every function mirrors the MemPool assembly kernels' integer semantics
exactly (wrapping int32, arithmetic right shifts), so a value computed by
the rust simulator, by the Pallas kernel, and by these references must be
bit-identical.
"""

import jax.numpy as jnp
import numpy as np


def matmul(a, b):
    return jnp.matmul(
        a.astype(jnp.int32), b.astype(jnp.int32), preferred_element_type=jnp.int32
    )


def axpy(alpha, x, y):
    return (jnp.int32(alpha) * x + y).astype(jnp.int32)


def dotp(x, y):
    return jnp.sum(x * y).astype(jnp.int32)


def conv2d_3x3(img, coeff):
    """'Same'-size 3x3 convolution over int32; borders left zero
    (MemPool's kernel computes interior pixels only)."""
    h, w = img.shape
    out = jnp.zeros((h, w), jnp.int32)
    acc = jnp.zeros((h - 2, w - 2), jnp.int32)
    for dr in range(3):
        for dc in range(3):
            acc = acc + coeff[dr][dc] * img[dr : h - 2 + dr, dc : w - 2 + dc]
    return out.at[1 : h - 1, 1 : w - 1].set(acc)


def dct_coeff_table(shift=7):
    """The integer DCT-II matrix used by the rust kernel (see
    rust/src/kernels/dct.rs::coeff_table)."""
    c = np.zeros((8, 8), np.int32)
    for u in range(8):
        s = np.sqrt(0.5) if u == 0 else 1.0
        for x in range(8):
            val = s * np.cos((2 * x + 1) * u * np.pi / 16.0) * (1 << shift) * 0.5
            c[u, x] = int(np.round(val))
    return jnp.asarray(c)


def dct8x8(block, shift=7):
    """2D integer DCT of one 8x8 block with per-pass arithmetic shifts,
    mirroring the simulator kernel exactly."""
    c = dct_coeff_table(shift)
    # Row pass: mid[r, u] = (sum_i x[r, i] * C[u, i]) >> shift.
    mid = jnp.right_shift(jnp.matmul(block, c.T, preferred_element_type=jnp.int32), shift)
    # Column pass: out[v, u] = (sum_r mid[r, u] * C[v, r]) >> shift.
    out = jnp.right_shift(jnp.matmul(c, mid, preferred_element_type=jnp.int32), shift)
    return out
