"""Layer-1 Pallas kernel: the tiled int32 matmul.

This is MemPool's compute hot-spot (Table 1's matmul) re-thought for a
TPU-shaped memory hierarchy, per the hardware-adaptation rule: MemPool
keeps each core's 4x4 output tile in the register file and streams A/B
operands through the tile-local SPM banks; the Pallas kernel keeps a
(bm, bn) output tile resident in VMEM and streams (bm, bk)/(bk, bn)
operand tiles HBM->VMEM through its BlockSpec grid - the same blocking
idea one level up the hierarchy (see DESIGN.md section Hardware-
Adaptation for the VMEM/MXU utilization estimate).

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which both jax and the
rust `xla`-crate runtime execute bit-identically (int32 is exact).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_steps):
    """One (i, j, k) grid step: o[i,j] += A[i,k] @ B[k,j].

    The output block is revisited across the k axis (standard Pallas
    accumulate-into-output pattern), playing the role of MemPool's
    16-register accumulator tile.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.int32)


def matmul(a, b, *, bm=32, bn=32, bk=32):
    """C[M,N] = A[M,K] @ B[K,N] over wrapping int32 (MemPool semantics)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, b)
