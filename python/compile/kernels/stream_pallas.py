"""Layer-1 Pallas kernels for the streaming workloads (axpy / dotp).

MemPool stripes axpy's vectors so every core streams from its own tile's
banks; on the TPU-shaped hierarchy the analogue is a 1D BlockSpec grid
streaming vector tiles HBM->VMEM with element-wise VPU work per tile.
dotp adds the reduction: per-tile partial dot products accumulated into
a single scalar output block (revisited across the grid, like MemPool's
amoadd reduction tree collapsing into one bank).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


def axpy(alpha, x, y, *, block=1024):
    """y + alpha * x over wrapping int32, tiled in `block`-element chunks."""
    (n,) = x.shape
    block = min(block, n)
    assert n % block == 0
    alpha = jnp.asarray(alpha, jnp.int32).reshape((1,))
    return pl.pallas_call(
        _axpy_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(alpha, x, y)


def _dotp_kernel(x_ref, y_ref, o_ref, *, steps):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...] * y_ref[...]).reshape((1,))


def dotp(x, y, *, block=1024):
    """sum(x * y) over wrapping int32."""
    (n,) = x.shape
    block = min(block, n)
    assert n % block == 0
    steps = n // block
    out = pl.pallas_call(
        functools.partial(_dotp_kernel, steps=steps),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        interpret=True,
    )(x, y)
    return out[0]
