"""Layer-1 correctness: the Pallas kernels against the pure-jnp oracles.

Hypothesis sweeps shapes and block sizes; int32 semantics make every
comparison exact (assert_array_equal, not allclose)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.matmul_pallas import matmul as pallas_matmul
from compile.kernels.stream_pallas import axpy as pallas_axpy
from compile.kernels.stream_pallas import dotp as pallas_dotp

SETTINGS = settings(max_examples=12, deadline=None)


def rand_i32(rng, shape, lo=-1000, hi=1000):
    return jnp.asarray(rng.integers(lo, hi, size=shape, dtype=np.int64).astype(np.int32))


@SETTINGS
@given(
    m=st.sampled_from([4, 8, 16, 32, 64]),
    n=st.sampled_from([4, 8, 16, 32]),
    k=st.sampled_from([4, 8, 16, 32]),
    bsel=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, n, k, bsel, seed):
    rng = np.random.default_rng(seed)
    a = rand_i32(rng, (m, k))
    b = rand_i32(rng, (k, n))
    got = pallas_matmul(a, b, bm=bsel, bn=bsel, bk=bsel)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.matmul(a, b)))


def test_matmul_wraps_like_the_simulator():
    # Wrapping int32 overflow must match two's-complement semantics.
    a = jnp.full((4, 4), 2**30, jnp.int32)
    b = jnp.full((4, 4), 4, jnp.int32)
    got = np.asarray(pallas_matmul(a, b))
    acc = np.int64(2**30) * 4 * 4  # 2^34
    expect = np.full((4, 4), np.int32(acc & 0xFFFFFFFF if acc & 0x80000000 else acc % 2**32))
    wrapped = np.int32((acc % 2**32) - 2**32 if (acc % 2**32) >= 2**31 else acc % 2**32)
    np.testing.assert_array_equal(got, np.full((4, 4), wrapped))


@SETTINGS
@given(
    n=st.sampled_from([64, 256, 1024, 4096]),
    block=st.sampled_from([64, 256, 1024]),
    alpha=st.integers(-7, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_axpy_matches_ref(n, block, alpha, seed):
    rng = np.random.default_rng(seed)
    x = rand_i32(rng, (n,))
    y = rand_i32(rng, (n,))
    got = pallas_axpy(alpha, x, y, block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.axpy(alpha, x, y)))


@SETTINGS
@given(
    n=st.sampled_from([64, 256, 1024]),
    block=st.sampled_from([64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dotp_matches_ref(n, block, seed):
    rng = np.random.default_rng(seed)
    x = rand_i32(rng, (n,), -100, 100)
    y = rand_i32(rng, (n,), -100, 100)
    got = pallas_dotp(x, y, block=block)
    assert int(got) == int(ref.dotp(x, y))


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1))
def test_dct_ref_matches_rust_table(seed):
    # The coefficient table must equal the rust kernel's (spot values).
    c = np.asarray(ref.dct_coeff_table())
    assert c[0, 0] == 45  # round(sqrt(.5) * 64)
    assert c.shape == (8, 8)
    rng = np.random.default_rng(seed)
    blk = rand_i32(rng, (8, 8), -128, 128)
    out = np.asarray(ref.dct8x8(blk))
    # Row/column passes shift arithmetically: recompute in numpy.
    cc = c.astype(np.int64)
    x = np.asarray(blk).astype(np.int64)
    mid = ((x @ cc.T).astype(np.int32)) >> 7
    expect = ((cc.astype(np.int32) @ mid).astype(np.int32)) >> 7
    np.testing.assert_array_equal(out, expect)


def test_conv2d_ref_interior_only():
    img = jnp.arange(16 * 16, dtype=jnp.int32).reshape(16, 16)
    coeff = [[1, 2, 1], [2, 4, 2], [1, 2, 1]]
    out = np.asarray(ref.conv2d_3x3(img, coeff))
    assert out[0].sum() == 0 and out[-1].sum() == 0
    # Hand-check one interior pixel.
    acc = 0
    for dr in range(3):
        for dc in range(3):
            acc += coeff[dr][dc] * int(img[4 + dr - 1, 5 + dc - 1])
    assert out[4, 5] == acc


def test_registry_lowers():
    """Every golden model lowers to HLO text (the aot.py path)."""
    import jax
    from compile.aot import to_hlo_text
    from compile.model import registry

    for name, (fn, shapes) in registry().items():
        text = to_hlo_text(jax.jit(fn).lower(*shapes))
        assert "ENTRY" in text, name
        assert len(text) > 200, name
